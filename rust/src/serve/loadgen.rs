//! Open-loop load generation and the serve-bench drivers.
//!
//! **Open loop**: the arrival schedule is generated up front from
//! `(seed, qps, shape, duration)` — a pure function, so every arm of a
//! comparison (fixed vs. adaptive governor) faces the *identical* request
//! stream, exactly like the trainer's paired-trial methodology. Shapes
//! are sampled by Poisson thinning (candidates at the peak rate, accepted
//! with probability `rate(t)/rate_max`), which is exact for steady,
//! bursty and ramp profiles alike.
//!
//! Two drivers run the same queue → governor → batcher → inference
//! pipeline:
//!
//! * [`run_virtual`] — a discrete-event loop on a **virtual clock**: the
//!   forward pass really executes (reference backend), but time advances
//!   by a deterministic affine service model `base + per_sample·padded`.
//!   The whole run — batch compositions, governor decisions, latency
//!   percentiles, the JSON report — is a pure function of (seed, config):
//!   the serving twin of the trainer's determinism contract, and what CI
//!   pins (`tests/serve_determinism.rs`).
//! * the **wall clock** path ([`super::server::serve_wall`]) — real
//!   scoped threads, real `Instant` latencies, for actual measurement;
//!   arrivals are paced by sleeping and shed (never delayed) when the
//!   admission queue is full.
//!
//! [`run_serve_bench`] wraps either into a stable JSON report whose
//! percentiles feed the cross-PR `BENCH_*.json` trajectory.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::governor::{
    pad_to_rung, FixedServeGovernor, QueueDepthGovernor, ServeGovernor, ServeObservation,
    SloGovernor,
};
use super::lifecycle::{AdmissionPolicy, Control, LifecyclePlan};
use super::queue::BoundedQueue;
use super::server::serve_wall;
use super::{Request, ServeStats};
use crate::config::{ModelArch, ServeConfig, TrafficShape};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::dataset::{GatherBufs, TrainData};
use crate::data::synthetic::{generate, SyntheticSpec, IMG_LEN};
use crate::obs::trace::{SpanPayload, TraceBuf};
use crate::obs::{write_prometheus, write_serve_trace, MetricsRegistry};
use crate::optim::param::ParamSet;
use crate::runtime::kernels;
use crate::runtime::{ModelRuntime, Workspace};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Which clock drives the bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Clock {
    /// deterministic discrete-event time (bit-identical reports)
    Virtual,
    /// real threads and `Instant` latencies
    Wall,
}

impl Clock {
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "virtual" => Clock::Virtual,
            "wall" => Clock::Wall,
            other => bail!("unknown clock {other:?} (virtual|wall)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Clock::Virtual => "virtual",
            Clock::Wall => "wall",
        }
    }
}

/// Construct a serve governor by CLI name over a config's knobs. The
/// `fixed` baseline serves `min_batch` (the `--batch` knob).
pub fn governor_from_name(name: &str, scfg: &ServeConfig) -> Result<Box<dyn ServeGovernor>> {
    Ok(match name {
        "fixed" => Box::new(FixedServeGovernor::new(scfg.min_batch)),
        "queue" => Box::new(QueueDepthGovernor::new(scfg.min_batch, scfg.max_batch)),
        "slo" => Box::new(SloGovernor::new(
            scfg.slo_ns(),
            scfg.min_batch,
            scfg.max_batch,
            scfg.window,
        )),
        other => bail!("unknown serve governor {other:?} (fixed|queue|slo)"),
    })
}

/// Deterministic open-loop arrival schedule: ns offsets from bench start,
/// non-decreasing, all within the duration window.
pub fn arrival_schedule(qps: f64, duration_s: f64, shape: TrafficShape, seed: u64) -> Vec<u64> {
    assert!(qps > 0.0 && duration_s > 0.0);
    let mut rng = Pcg32::new(seed).split(0x4C47);
    let rate_max = match shape {
        TrafficShape::Steady => qps,
        TrafficShape::Bursty => 1.8 * qps,
        TrafficShape::Ramp => 2.0 * qps,
    };
    let rate = |t: f64| -> f64 {
        match shape {
            TrafficShape::Steady => qps,
            // alternating 500 ms high/low periods with mean qps
            TrafficShape::Bursty => {
                if (t / 0.5) as u64 % 2 == 0 {
                    1.8 * qps
                } else {
                    0.2 * qps
                }
            }
            TrafficShape::Ramp => 2.0 * qps * t / duration_s,
        }
    };
    // Poisson thinning: exact for any bounded rate profile
    let mut out = Vec::with_capacity((qps * duration_s) as usize + 16);
    let mut t = 0.0f64;
    loop {
        let u = rng.next_f64();
        t += -(1.0 - u).ln() / rate_max;
        if t >= duration_s {
            break;
        }
        if rng.next_f64() * rate_max <= rate(t) {
            out.push((t * 1e9) as u64);
        }
    }
    out
}

/// Virtual-clock knobs (all ns).
#[derive(Debug, Clone)]
pub struct VirtualCfg {
    pub workers: usize,
    pub max_wait_ns: u64,
    /// per-batch dispatch overhead
    pub service_base_ns: u64,
    /// cost per padded sample
    pub service_per_sample_ns: u64,
    /// serving stops here; still-queued requests count as unserved
    pub horizon_ns: u64,
    /// requests arriving earlier are excluded from the latency histogram
    pub warmup_ns: u64,
    /// admission cap, mirroring the wall queue: arrivals beyond it shed
    pub queue_capacity: usize,
    /// intra-op kernel threads for the driver's forward passes (cannot
    /// change any observable: kernels are bitwise thread-invariant)
    pub kernel_threads: usize,
}

impl VirtualCfg {
    pub fn from_serve(scfg: &ServeConfig) -> Self {
        VirtualCfg {
            workers: scfg.workers,
            max_wait_ns: scfg.max_wait_ns(),
            service_base_ns: (scfg.service_base_us * 1e3) as u64,
            service_per_sample_ns: (scfg.service_per_sample_us * 1e3) as u64,
            horizon_ns: scfg.horizon_ns(),
            warmup_ns: scfg.warmup_ns(),
            queue_capacity: scfg.queue_capacity,
            kernel_threads: scfg.kernel_threads,
        }
    }
}

/// Virtual-time gap between in-run telemetry snapshots: every 250 ms of
/// event time the trace records queue depth, completions and the running
/// p99 — deterministic because the boundaries live on the virtual clock.
const SNAPSHOT_INTERVAL_NS: u64 = 250_000_000;

/// A failed batch waiting out its backoff before the next attempt.
struct RetryBatch {
    /// earliest virtual instant the next attempt may dispatch
    ready_ns: u64,
    /// the batch's sequence number (assigned at first dispatch; the
    /// fault plan is keyed on it, so every attempt replays identically)
    seq: u64,
    /// the attempt about to run (1 = first dispatch)
    attempt: u32,
    reqs: Vec<Request>,
}

/// Admit one arrival under the configured policy (virtual clock). The
/// `Block` policy has no producer to park in a discrete-event model, so
/// it admits unconditionally — capacity exists to model the shedding
/// policies, not physical memory.
#[allow(clippy::too_many_arguments)]
fn admit_virtual(
    r: Request,
    now: u64,
    policy: AdmissionPolicy,
    capacity: usize,
    pending: &mut VecDeque<Request>,
    stats: &mut ServeStats,
    shed: &mut u64,
    trace: &mut TraceBuf,
) {
    if pending.len() < capacity {
        pending.push_back(r);
        return;
    }
    match policy {
        AdmissionPolicy::Block => pending.push_back(r),
        AdmissionPolicy::ShedNewest => {
            *shed += 1;
            trace.record_at(
                SpanPayload::Shed { id: r.id, depth: pending.len() as u32, evicted: false },
                now,
                0,
            );
        }
        AdmissionPolicy::ShedOldest => {
            let victim = pending.pop_front().expect("full queue has a front");
            stats.evicted += 1;
            trace.record_at(
                SpanPayload::Shed { id: victim.id, depth: pending.len() as u32, evicted: true },
                now,
                0,
            );
            pending.push_back(r);
        }
        AdmissionPolicy::DeadlineAware { deadline_ns } => {
            while pending.len() >= capacity {
                match pending.front() {
                    Some(front) if front.arrival_ns.saturating_add(deadline_ns) <= now => {
                        let victim = pending.pop_front().expect("front exists");
                        stats.evicted += 1;
                        trace.record_at(
                            SpanPayload::Shed {
                                id: victim.id,
                                depth: pending.len() as u32,
                                evicted: true,
                            },
                            now,
                            0,
                        );
                    }
                    _ => break,
                }
            }
            if pending.len() < capacity {
                pending.push_back(r);
            } else {
                *shed += 1;
                trace.record_at(
                    SpanPayload::Shed { id: r.id, depth: pending.len() as u32, evicted: false },
                    now,
                    0,
                );
            }
        }
    }
}

/// If the drain point has been reached, refuse (and count) every
/// remaining arrival; the arrival schedule is sorted, so one arrival at
/// or past `drain_at` means all the rest are too. Returns true if the
/// remainder was flushed.
#[allow(clippy::too_many_arguments)]
fn drain_flush(
    arrivals: &[u64],
    i: &mut usize,
    drain_at: u64,
    pending_len: usize,
    shed: &mut u64,
    drain_logged: &mut bool,
    trace: &mut TraceBuf,
) -> bool {
    let n = arrivals.len();
    if *i < n && arrivals[*i] >= drain_at {
        if !*drain_logged {
            trace.record_at(SpanPayload::Drain { pending: pending_len as u32 }, drain_at, 0);
            *drain_logged = true;
        }
        *shed += (n - *i) as u64;
        *i = n;
        return true;
    }
    false
}

/// Clamp a dispatch instant out of the suspension window: nothing may
/// dispatch in `[suspend, resume)`. The spans are recorded only when the
/// window actually deflects a dispatch, so a suspension that nothing
/// runs into leaves the whole run (trace included) bitwise unchanged.
fn apply_suspend(
    t: u64,
    window: Option<(u64, u64)>,
    logged: &mut bool,
    trace: &mut TraceBuf,
) -> u64 {
    if let Some((s, r)) = window {
        if t >= s && t < r {
            if !*logged {
                trace.record_at(SpanPayload::Suspend, s, r - s);
                trace.record_at(SpanPayload::Resume, r, 0);
                *logged = true;
            }
            return r;
        }
    }
    t
}

/// Discrete-event serving run on the virtual clock. The batcher policy is
/// [`super::batcher::batch_ready`] evaluated in event time: a batch closes
/// at the earliest instant it is full, its front request has waited
/// `max_wait`, or no more arrivals can come. `workers` parallel servers
/// are modeled as a min-heap of busy-until times; the forward pass runs
/// for real on the reference backend, the service *time* comes from the
/// affine model. The lifecycle `plan` layers admission policy, per-batch
/// retry with backoff, graceful drain, suspend/resume and hot reload on
/// top (DESIGN.md §13) — all of it event-time arithmetic, so everything
/// observable stays a pure function of (seed, config, fault plan).
#[allow(clippy::too_many_arguments)]
pub fn run_virtual(
    rt: &ModelRuntime,
    params: &ParamSet,
    data: &TrainData,
    governor: &mut Box<dyn ServeGovernor>,
    arrivals: &[u64],
    samples: &[usize],
    ladder: &[usize],
    cfg: &VirtualCfg,
    plan: &LifecyclePlan,
    trace: &mut TraceBuf,
) -> Result<ServeStats> {
    assert!(cfg.workers > 0, "need at least one virtual server");
    assert_eq!(arrivals.len(), samples.len());
    let n = arrivals.len();

    let mut pending: VecDeque<Request> = VecDeque::new();
    let mut retryq: Vec<RetryBatch> = Vec::new();
    let mut workers: BinaryHeap<Reverse<u64>> =
        (0..cfg.workers).map(|_| Reverse(0u64)).collect();
    let mut stats = ServeStats::default();
    let mut bufs = GatherBufs::default();
    // the virtual driver serves every batch on one thread: one arena
    let mut ws = Workspace::with_kernel_threads(cfg.kernel_threads);
    let mut lats: Vec<u64> = Vec::new();
    let mut i = 0usize;
    let mut shed = 0u64;
    let mut next_snapshot = SNAPSHOT_INTERVAL_NS;
    let mut snapshot_idx = 0u32;
    let mut batch_seq = 0u64;
    let mut pad_ladder: Vec<usize> = ladder.to_vec();
    let mut reload_pending = plan.reload.clone();
    let mut drain_logged = false;
    let mut suspend_logged = false;

    // admit every arrival at or before `t` under the lifecycle plan
    macro_rules! admit_until {
        ($t:expr) => {
            while i < n && arrivals[i] <= $t {
                if let Some(d) = plan.drain_at_ns {
                    if drain_flush(
                        arrivals,
                        &mut i,
                        d,
                        pending.len(),
                        &mut shed,
                        &mut drain_logged,
                        trace,
                    ) {
                        break;
                    }
                }
                let r = Request { id: i as u64, sample: samples[i], arrival_ns: arrivals[i] };
                admit_virtual(
                    r,
                    arrivals[i],
                    plan.admission,
                    cfg.queue_capacity,
                    &mut pending,
                    &mut stats,
                    &mut shed,
                    trace,
                );
                i += 1;
            }
        };
    }

    loop {
        let Reverse(free_at) = *workers.peek().expect("worker heap is never empty");
        admit_until!(free_at);
        // a future arrival at/past the drain point will never be
        // admitted, so the fill estimate must not wait for it
        if let Some(d) = plan.drain_at_ns {
            drain_flush(arrivals, &mut i, d, pending.len(), &mut shed, &mut drain_logged, trace);
        }
        let closed = i >= n;
        let target = governor.target_batch(pending.len()).max(1);

        // earliest retry whose backoff can have elapsed (ties by seq)
        let retry_pick = retryq
            .iter()
            .enumerate()
            .min_by_key(|(_, rb)| (rb.ready_ns, rb.seq))
            .map(|(idx, rb)| (idx, rb.ready_ns.max(free_at)));

        // earliest instant a *new* batch can close: it is already full,
        // it fills, its front (or first future) request hits max_wait,
        // or no arrival can ever come (serve the leftovers)
        let new_t: Option<u64> = if pending.len() >= target {
            Some(free_at)
        } else if closed {
            if pending.is_empty() {
                None
            } else {
                Some(free_at)
            }
        } else {
            let t_fill = arrivals.get(i + (target - pending.len()) - 1).copied();
            let t_timeout = pending
                .front()
                .map(|r| r.arrival_ns + cfg.max_wait_ns)
                .unwrap_or(arrivals[i] + cfg.max_wait_ns);
            Some(
                match t_fill {
                    Some(fill) => fill.min(t_timeout),
                    None => t_timeout,
                }
                .max(free_at),
            )
        };

        // dispatch whichever is ready first; a retry wins ties so a
        // requeued batch is never starved by fresh traffic
        let (t0, is_retry, retry_idx) = match (retry_pick, new_t) {
            (None, None) => break, // fully served: no pending, no retries, no arrivals
            (Some((idx, tr)), None) => (tr, true, idx),
            (None, Some(tn)) => (tn, false, 0),
            (Some((idx, tr)), Some(tn)) => {
                if tr <= tn {
                    (tr, true, idx)
                } else {
                    (tn, false, 0)
                }
            }
        };

        // hot reload applies at the first dispatch consultation at/past
        // its scheduled instant: swap governor + pad ladder, then
        // re-derive the decision under the new regime
        if matches!(&reload_pending, Some((at, _)) if t0 >= *at) {
            let (at, spec) = reload_pending.take().expect("reload is pending");
            *governor = spec.build_governor()?;
            pad_ladder = spec.ladder();
            stats.reloads += 1;
            trace.record_at(
                SpanPayload::Reload {
                    min_batch: spec.min_batch as u32,
                    max_batch: spec.max_batch as u32,
                    slo_ns: (spec.slo_ms * 1e6) as u64,
                },
                at,
                0,
            );
            continue;
        }

        let (t, batch, seq, attempt, depth_after) = if is_retry {
            let t = apply_suspend(t0, plan.suspend_ns, &mut suspend_logged, trace);
            if plan.drain_at_ns.is_none() && t >= cfg.horizon_ns {
                let queued: usize = retryq.iter().map(|rb| rb.reqs.len()).sum();
                stats.unserved = (pending.len() + (n - i) + queued) as u64;
                break;
            }
            let rb = retryq.swap_remove(retry_idx);
            (t, rb.reqs, rb.seq, rb.attempt, pending.len())
        } else {
            admit_until!(t0);
            // the closing-time candidates all sit at or after the next
            // arrival, so something is always pending by now
            assert!(!pending.is_empty(), "virtual batcher closed an empty batch");
            let t = apply_suspend(t0, plan.suspend_ns, &mut suspend_logged, trace);
            if plan.drain_at_ns.is_none() && t >= cfg.horizon_ns {
                let queued: usize = retryq.iter().map(|rb| rb.reqs.len()).sum();
                stats.unserved = (pending.len() + (n - i) + queued) as u64;
                break;
            }
            let take = pending.len().min(target);
            let batch: Vec<Request> = pending.drain(..take).collect();
            // causality clamp: a batch only exists once its last member
            // has arrived (pending is FIFO, so the last taken has the
            // max arrival). Without this, a second worker freeing
            // earlier than the admission instant could "serve" requests
            // before they arrive and `done - arrival` would underflow.
            let t = t.max(batch.last().expect("batch is non-empty").arrival_ns);
            // the causality clamp can land inside the suspension window
            let t = apply_suspend(t, plan.suspend_ns, &mut suspend_logged, trace);
            let seq = batch_seq;
            batch_seq += 1;
            (t, batch, seq, 1u32, pending.len())
        };

        let take = batch.len();
        let padded = pad_to_rung(take, &pad_ladder);
        let service = cfg.service_base_ns + cfg.service_per_sample_ns * padded as u64;
        let done = t + service;
        workers.pop();
        workers.push(Reverse(done));

        // injected fault: the dispatch consumes its service time (the
        // worker was busy failing) but produces no completions
        if plan.fault.is_some_and(|f| f.should_fail(seq, attempt)) {
            stats.failed_batches += 1;
            if attempt >= plan.retry.budget {
                bail!(
                    "retry budget exhausted: batch {seq} ({take} request(s)) failed \
                     attempt {attempt} of {}",
                    plan.retry.budget
                );
            }
            stats.retries += 1;
            trace.record_at(
                SpanPayload::Retry { seq, attempt, batch: take as u32 },
                done,
                0,
            );
            let ready_ns = done + plan.retry.backoff_for(attempt);
            retryq.push(RetryBatch { ready_ns, seq, attempt: attempt + 1, reqs: batch });
            continue;
        }

        // the forward pass really runs; only its *duration* is modeled
        let out = super::forward_batch(rt, params, data, &batch, padded, &mut bufs, &mut ws)?;

        lats.clear();
        for r in &batch {
            lats.push(done - r.arrival_ns);
        }
        for (r, &l) in batch.iter().zip(&lats) {
            if r.arrival_ns >= cfg.warmup_ns {
                stats.hist.record(l);
            }
        }
        stats.completed += take as u64;
        stats.batches += 1;
        stats.padded_samples += padded as u64;
        stats.loss_sum += out.loss;
        stats.correct_sum += out.correct as f64;
        stats.last_done_ns = stats.last_done_ns.max(done);
        // telemetry is a pure side channel on the virtual clock: batch
        // spans and snapshot rows carry event-time stamps, so two seeded
        // runs serialize to byte-identical JSONL (DESIGN.md §12)
        trace.record_at(
            SpanPayload::ServeBatch {
                batch: take as u32,
                padded: padded as u32,
                depth: depth_after as u32,
            },
            t,
            service,
        );
        while done >= next_snapshot {
            trace.record_at(
                SpanPayload::Snapshot {
                    idx: snapshot_idx,
                    completed: stats.completed,
                    batches: stats.batches,
                    shed,
                    depth: depth_after as u32,
                    p99_ns: stats.hist.p99(),
                },
                next_snapshot,
                0,
            );
            snapshot_idx += 1;
            next_snapshot += SNAPSHOT_INTERVAL_NS;
        }
        let decisions_before = governor.decisions();
        governor.observe(ServeObservation {
            batch: take,
            queue_depth: depth_after,
            latencies_ns: &lats,
        });
        if governor.decisions() != decisions_before {
            trace.record_at(
                SpanPayload::GovernorDecision {
                    batch: governor.current_batch() as u32,
                    decisions: governor.decisions() as u32,
                    lr: f64::NAN, // no learning rate on the serve path
                },
                done,
                0,
            );
        }
    }
    stats.shed = shed;
    stats.drained = plan.drain_at_ns.is_some() && stats.unserved == 0;
    stats.pack_count = ws.stats().pack_count;
    stats.alloc_bytes = ws.alloc_bytes();
    Ok(stats)
}

/// End-to-end serve bench: build the sample pool and reference runtime,
/// generate the arrival schedule, run the pipeline under `governor` on
/// the chosen clock, and render the stable JSON report. `checkpoint`
/// optionally serves parameters trained by `adabatch train
/// --checkpoint-dir` instead of a fresh init.
pub fn run_serve_bench(
    scfg: &ServeConfig,
    governor: &mut Box<dyn ServeGovernor>,
    clock: Clock,
    classes: usize,
    pool: usize,
    checkpoint: Option<&std::path::Path>,
) -> Result<(ServeStats, Json)> {
    scfg.validate()?;
    if classes < 2 || pool == 0 {
        bail!("serve-bench needs ≥ 2 classes and a non-empty sample pool");
    }
    let plan = LifecyclePlan::from_serve(scfg)?;
    let governor_initial = governor.name().to_string();
    // padding uses the live governor's ladder; the runtime's executable
    // ladder is the union with the reload target's, so a hot reload
    // never requests a batch size without a pre-built executable
    let ladder = governor.ladder();
    let mut exec_ladder = ladder.clone();
    if let Some((_, spec)) = &plan.reload {
        exec_ladder.extend(spec.ladder());
        exec_ladder.sort_unstable();
        exec_ladder.dedup();
    }
    let arrivals = arrival_schedule(scfg.qps, scfg.duration_s, scfg.shape, scfg.seed);
    let n = arrivals.len();

    // shared sample pool: requests reference it by index
    let mut spec = SyntheticSpec::cifar10();
    spec.n_classes = classes;
    spec.train_per_class = pool.div_ceil(classes).max(1);
    spec.test_per_class = 1;
    spec.seed = 0x5E27E ^ scfg.seed;
    let data = TrainData::Images(generate(&spec).train);
    let pool_len = data.len();
    let mut srng = Pcg32::new(scfg.seed).split(0x5A3B);
    let samples: Vec<usize> = (0..n)
        .map(|_| srng.gen_range(pool_len as u32) as usize)
        .collect();

    let rt = match scfg.arch {
        ModelArch::Linear => {
            ModelRuntime::reference_serving("serve_ref", IMG_LEN, classes, &exec_ladder)
        }
        ModelArch::Mlp { hidden } => ModelRuntime::reference_serving_mlp(
            "serve_ref_mlp",
            IMG_LEN,
            hidden,
            classes,
            &exec_ladder,
        ),
    };
    let mut params = ParamSet::init(&rt.entry.params, scfg.seed);
    if let Some(path) = checkpoint {
        let ck = Checkpoint::load(path, &params)?;
        log::info!(
            "serving params from checkpoint {} (model {:?}, epoch {})",
            path.display(),
            ck.model,
            ck.epoch
        );
        params = ck.params;
    }

    // trace buffer for the virtual driver; the wall path gets a disabled
    // buffer (its timestamps are not deterministic, so a wall trace would
    // break the byte-identical contract — metrics still work)
    let mut trace = TraceBuf::new(match clock {
        Clock::Virtual => scfg.telemetry.trace_capacity(),
        Clock::Wall => 0,
    });
    let stats = match clock {
        Clock::Virtual => {
            let vcfg = VirtualCfg::from_serve(scfg);
            run_virtual(
                &rt, &params, &data, governor, &arrivals, &samples, &ladder, &vcfg, &plan,
                &mut trace,
            )?
        }
        Clock::Wall => {
            let queue: BoundedQueue<Request> = BoundedQueue::bounded(scfg.queue_capacity);
            let max_wait = Duration::from_nanos(scfg.max_wait_ns());
            let start = Instant::now();
            let deadline = start + Duration::from_nanos(scfg.horizon_ns());
            // the control plan becomes a timeline of wall-clock sends
            let mut controls: Vec<(u64, Control)> = Vec::new();
            if let Some((s, r)) = plan.suspend_ns {
                controls.push((s, Control::Suspend));
                controls.push((r, Control::Resume));
            }
            if let Some((at, spec)) = &plan.reload {
                controls.push((*at, Control::Reload(spec.clone())));
            }
            if let Some(d) = plan.drain_at_ns {
                controls.push((d, Control::Drain));
            }
            controls.sort_by_key(|(t, _)| *t);
            let (ctl_tx, ctl_rx) = channel::<Control>();
            let mut shed = 0u64;
            let mut evicted = 0u64;
            let mut stats = std::thread::scope(|s| {
                let server = s.spawn(|| {
                    serve_wall(
                        &rt,
                        &params,
                        &data,
                        governor,
                        &queue,
                        scfg.workers,
                        scfg.kernel_threads,
                        max_wait,
                        &ladder,
                        start,
                        scfg.warmup_ns(),
                        deadline,
                        &plan,
                        Some(ctl_rx),
                    )
                });
                if controls.is_empty() {
                    drop(ctl_tx);
                } else {
                    s.spawn(move || {
                        for (t_ns, c) in controls {
                            let due = Duration::from_nanos(t_ns);
                            let now = start.elapsed();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                            if ctl_tx.send(c).is_err() {
                                break; // server already gone
                            }
                        }
                    });
                }
                for (i, &t_ns) in arrivals.iter().enumerate() {
                    let due = Duration::from_nanos(t_ns);
                    let now = start.elapsed();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    // stamp the *scheduled* arrival, not the push time:
                    // if the generator falls behind, the lateness must
                    // show up as request latency (no coordinated
                    // omission), matching the virtual clock
                    let req = Request { id: i as u64, sample: samples[i], arrival_ns: t_ns };
                    // open loop: the client is never slowed past the
                    // bench deadline, whatever the admission policy
                    match plan.admission {
                        AdmissionPolicy::Block => {
                            if queue.push_deadline(req, deadline).is_err() {
                                shed += 1;
                            }
                        }
                        AdmissionPolicy::ShedNewest => {
                            if queue.try_push(req).is_err() {
                                shed += 1;
                            }
                        }
                        AdmissionPolicy::ShedOldest => match queue.push_evicting(req, |_| true) {
                            Ok(victims) => evicted += victims.len() as u64,
                            Err(_) => shed += 1,
                        },
                        AdmissionPolicy::DeadlineAware { deadline_ns } => {
                            let now_ns = start.elapsed().as_nanos() as u64;
                            let expired =
                                |r: &Request| r.arrival_ns.saturating_add(deadline_ns) <= now_ns;
                            match queue.push_evicting(req, expired) {
                                Ok(victims) => evicted += victims.len() as u64,
                                Err(_) => shed += 1,
                            }
                        }
                    }
                }
                queue.close();
                server
                    .join()
                    .unwrap_or_else(|payload| std::panic::resume_unwind(payload))
            })?;
            stats.shed = shed;
            stats.evicted += evicted;
            // arrivals admitted after the server hit its horizon cutoff
            stats.unserved += queue.try_drain(usize::MAX).len() as u64;
            stats
        }
    };
    if let Some(path) = &scfg.telemetry.trace_out {
        match clock {
            Clock::Virtual => {
                let events = trace.drain();
                write_serve_trace(path, &events)?;
            }
            Clock::Wall => log::warn!(
                "--trace-out needs the virtual clock (wall timestamps are not \
                 deterministic); no trace written"
            ),
        }
    }
    if let Some(path) = &scfg.telemetry.metrics_out {
        let mut reg = MetricsRegistry::default();
        let completed = reg.counter("serve_completed_total");
        reg.inc(completed, stats.completed);
        let batches = reg.counter("serve_batches_total");
        reg.inc(batches, stats.batches);
        let shed = reg.counter("serve_shed_total");
        reg.inc(shed, stats.shed);
        let padded = reg.counter("serve_padded_samples_total");
        reg.inc(padded, stats.padded_samples);
        let retries = reg.counter("serve_retries_total");
        reg.inc(retries, stats.retries);
        let failed = reg.counter("serve_failed_batches_total");
        reg.inc(failed, stats.failed_batches);
        let evicted = reg.counter("serve_evicted_total");
        reg.inc(evicted, stats.evicted);
        let reloads = reg.counter("serve_reloads_total");
        reg.inc(reloads, stats.reloads);
        let unserved = reg.counter("serve_unserved_total");
        reg.inc(unserved, stats.unserved);
        let pack = reg.counter("workspace_pack_count_total");
        reg.inc(pack, stats.pack_count);
        let alloc = reg.gauge("workspace_alloc_bytes");
        reg.set(alloc, stats.alloc_bytes as f64);
        reg.absorb_histogram("serve_latency_ns", &stats.hist);
        write_prometheus(path, &reg)?;
    }
    let report = report_json(scfg, clock, governor.as_ref(), &governor_initial, &stats, n);
    Ok((stats, report))
}

/// The stable JSON report (keys are emitted sorted — `util::json` objects
/// are BTreeMaps — so virtual-clock reports are bit-identical per seed).
pub fn report_json(
    scfg: &ServeConfig,
    clock: Clock,
    governor: &dyn ServeGovernor,
    governor_initial: &str,
    stats: &ServeStats,
    requests: usize,
) -> Json {
    let ms = |ns: u64| ns as f64 / 1e6;
    let p99_ms = ms(stats.hist.p99());
    let loss_mean = if stats.batches == 0 { 0.0 } else { stats.loss_sum / stats.batches as f64 };
    Json::obj(vec![
        ("bench", Json::str("serve-bench")),
        ("clock", Json::str(clock.name())),
        ("model", Json::str(scfg.arch.name())),
        ("shape", Json::str(scfg.shape.name())),
        // the governor the run started under; after a hot reload,
        // `governor_final` names the one it ended under
        ("governor", Json::str(governor_initial)),
        ("governor_final", Json::str(governor.name())),
        ("admission", Json::str(scfg.lifecycle.admission.clone())),
        ("retry_budget", Json::num(scfg.lifecycle.retry_budget as f64)),
        ("qps", Json::num(scfg.qps)),
        ("duration_s", Json::num(scfg.duration_s)),
        // string, not Json::num: a u64 seed above 2^53 must round-trip
        // exactly for the reproduce-from-report workflow
        ("seed", Json::str(scfg.seed.to_string())),
        ("workers", Json::num(scfg.workers as f64)),
        // dispatch provenance: which kernel path served the run and how
        // many intra-op threads each server used (neither affects a bit
        // of output — DESIGN.md §8/§11 — but both affect wall timings)
        ("kernel_dispatch", Json::str(kernels::dispatch_name())),
        ("kernel_threads", Json::num(scfg.kernel_threads as f64)),
        ("min_batch", Json::num(scfg.min_batch as f64)),
        ("max_batch", Json::num(scfg.max_batch as f64)),
        ("max_wait_ms", Json::num(scfg.max_wait_ms)),
        ("window", Json::num(scfg.window as f64)),
        ("warmup_s", Json::num(scfg.warmup_s)),
        ("slo_ms", Json::num(scfg.slo_ms)),
        ("requests", Json::num(requests as f64)),
        ("completed", Json::num(stats.completed as f64)),
        ("shed", Json::num(stats.shed as f64)),
        ("evicted", Json::num(stats.evicted as f64)),
        ("unserved", Json::num(stats.unserved as f64)),
        ("retries", Json::num(stats.retries as f64)),
        ("failed_batches", Json::num(stats.failed_batches as f64)),
        ("reloads", Json::num(stats.reloads as f64)),
        ("drained", Json::Bool(stats.drained)),
        ("batches", Json::num(stats.batches as f64)),
        ("mean_batch", Json::num(stats.mean_batch())),
        ("final_batch", Json::num(governor.current_batch() as f64)),
        ("decisions", Json::num(governor.decisions() as f64)),
        ("throughput_rps", Json::num(stats.throughput_rps())),
        ("p50_ms", Json::num(ms(stats.hist.p50()))),
        ("p95_ms", Json::num(ms(stats.hist.p95()))),
        ("p99_ms", Json::num(p99_ms)),
        ("max_ms", Json::num(ms(stats.hist.max()))),
        ("mean_ms", Json::num(stats.hist.mean() / 1e6)),
        ("slo_met", Json::Bool(p99_ms <= scfg.slo_ms)),
        ("last_done_ms", Json::num(stats.last_done_ns as f64 / 1e6)),
        ("loss_mean", Json::num(loss_mean)),
        ("correct", Json::num(stats.correct_sum)),
        // workspace accounting (ISSUE 4): packs stay at one per tensor
        // per worker while serving, and the arena footprint is the
        // steady-state allocation the whole run holds
        ("pack_count", Json::num(stats.pack_count as f64)),
        ("alloc_bytes_steady_state", Json::num(stats.alloc_bytes as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_monotone() {
        for shape in [TrafficShape::Steady, TrafficShape::Bursty, TrafficShape::Ramp] {
            let a = arrival_schedule(500.0, 2.0, shape, 42);
            let b = arrival_schedule(500.0, 2.0, shape, 42);
            assert_eq!(a, b, "{shape:?}: same seed ⇒ same schedule");
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{shape:?}: non-decreasing");
            assert!(a.iter().all(|&t| t < 2_000_000_000), "{shape:?}: inside the window");
            // mean rate lands near the target (±25%)
            let n = a.len() as f64;
            assert!((n - 1000.0).abs() < 250.0, "{shape:?}: {n} arrivals for 1000 expected");
            let c = arrival_schedule(500.0, 2.0, shape, 43);
            assert_ne!(a, c, "{shape:?}: different seed ⇒ different schedule");
        }
    }

    #[test]
    fn bursty_is_actually_bursty() {
        let a = arrival_schedule(1000.0, 1.0, TrafficShape::Bursty, 7);
        let first_half = a.iter().filter(|&&t| t < 500_000_000).count();
        let second_half = a.len() - first_half;
        assert!(
            first_half > 3 * second_half,
            "high period {first_half} vs low period {second_half}"
        );
    }

    #[test]
    fn governor_names_resolve() {
        let scfg = ServeConfig::default();
        for name in ["fixed", "queue", "slo"] {
            let g = governor_from_name(name, &scfg).unwrap();
            assert!(!g.ladder().is_empty());
        }
        assert!(governor_from_name("psychic", &scfg).is_err());
        assert!(Clock::from_name("virtual").is_ok());
        assert!(Clock::from_name("sundial").is_err());
    }

    /// Statistical check (ISSUE 3 satellite): the thinning sampler's
    /// empirical arrival rate matches the configured rate across seeds,
    /// for every shape (they all share mean qps by construction).
    #[test]
    fn thinning_rate_matches_configured_rate_across_seeds() {
        // 2.0 s = a whole number of bursty high/low periods, so all three
        // shapes share the same mean rate by construction
        let (qps, dur) = (800.0, 2.0);
        let expect = qps * dur; // 1600 per seed
        for shape in [TrafficShape::Steady, TrafficShape::Bursty, TrafficShape::Ramp] {
            let seeds = 24u64;
            let total: usize = (0..seeds)
                .map(|s| arrival_schedule(qps, dur, shape, 1000 + s).len())
                .sum();
            let mean = total as f64 / seeds as f64;
            // Poisson σ per seed is √1600 = 40, so the 24-seed mean has
            // σ ≈ 8.2; a ±4% band (±64) is a ~7.8σ acceptance region
            let rel = (mean - expect).abs() / expect;
            assert!(rel < 0.04, "{shape:?}: mean arrivals {mean} vs configured {expect}");
        }
    }

    /// The ramp profile really ramps: rate(t) ∝ t puts ~1/4 of the mass
    /// in the first half-window and ~3/4 in the second.
    #[test]
    fn ramp_mass_is_linear_in_time() {
        let a = arrival_schedule(1000.0, 2.0, TrafficShape::Ramp, 17);
        let first = a.iter().filter(|&&t| t < 1_000_000_000).count() as f64;
        let frac = first / a.len() as f64;
        assert!((frac - 0.25).abs() < 0.05, "first-half fraction {frac}, expected ≈ 0.25");
    }

    /// The MLP serving arch keeps the virtual-clock determinism contract:
    /// a fixed (seed, config) renders a bit-identical report, and the
    /// report names the model family.
    #[test]
    fn mlp_serving_report_is_bit_identical_per_seed() {
        let scfg = ServeConfig {
            qps: 300.0,
            duration_s: 0.5,
            max_batch: 8,
            workers: 1,
            warmup_s: 0.0,
            arch: ModelArch::Mlp { hidden: 16 },
            ..ServeConfig::default()
        };
        scfg.validate().unwrap();
        let mut rendered = Vec::new();
        for _ in 0..2 {
            let mut gov = governor_from_name("slo", &scfg).unwrap();
            let (stats, rep) =
                run_serve_bench(&scfg, &mut gov, Clock::Virtual, 4, 32, None).unwrap();
            assert!(stats.completed > 0);
            assert!(stats.loss_sum > 0.0, "the MLP really ran");
            rendered.push(rep.to_string());
        }
        assert_eq!(rendered[0], rendered[1]);
        assert!(rendered[0].contains("\"model\":\"mlp\""));
    }

    #[test]
    fn virtual_bench_serves_everything_under_light_load() {
        let scfg = ServeConfig {
            qps: 400.0,
            duration_s: 0.5,
            max_batch: 8,
            workers: 1,
            warmup_s: 0.0,
            ..ServeConfig::default()
        };
        scfg.validate().unwrap();
        let mut gov = governor_from_name("queue", &scfg).unwrap();
        let (stats, report) =
            run_serve_bench(&scfg, &mut gov, Clock::Virtual, 4, 32, None).unwrap();
        assert!(stats.completed > 0);
        assert_eq!(stats.unserved, 0, "capacity far exceeds offered load");
        assert_eq!(stats.completed, stats.hist.count(), "warmup 0: all recorded");
        assert!(stats.hist.p99() > 0);
        assert!(stats.loss_sum > 0.0, "the model really ran");
        let s = report.to_string();
        assert!(s.contains("\"p99_ms\":"));
        assert!(s.contains("\"governor\":\"queue-depth\""));
    }
}
