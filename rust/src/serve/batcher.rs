//! Micro-batch assembly: drain the request queue into batches sized by
//! the governor, with a max-wait bound so a lone request never starves.
//!
//! Policy (shared by the wall-clock batcher thread and the virtual-time
//! bench driver, see [`batch_ready`]): a micro-batch *opens* when its
//! first request is taken and *closes* when either it reaches the
//! governor's target size or `max_wait` has elapsed since it opened —
//! whichever comes first. Under heavy load batches close full (throughput
//! mode); under trickle load they close on timeout with whatever arrived
//! (latency mode), which upper-bounds the batching delay any request can
//! be charged at `max_wait` plus one service time.

use std::time::{Duration, Instant};

use super::queue::{BoundedQueue, Pop};

/// Wall-clock micro-batcher over a [`BoundedQueue`].
#[derive(Debug, Clone)]
pub struct Batcher {
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_wait: Duration) -> Self {
        Batcher { max_wait }
    }

    /// Block until a micro-batch is ready: `Some(1..=target)` requests;
    /// `None` once the queue is closed and fully drained; `Some(vec![])`
    /// if `deadline` passes while the queue is still open and empty (the
    /// caller's horizon cutoff — without it an idle open queue would
    /// block forever).
    pub fn next_batch<T>(
        &self,
        queue: &BoundedQueue<T>,
        target: usize,
        deadline: Option<Instant>,
    ) -> Option<Vec<T>> {
        let target = target.max(1);
        // horizon check must precede the opening pop: under continuous
        // trickle load pop_up_to never times out, so checking the
        // deadline only on Pop::TimedOut would keep opening batches
        // past the horizon forever
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Some(Vec::new());
        }
        // wait (in max_wait slices, so a close is noticed promptly) for
        // the batch-opening request
        let mut batch: Vec<T> = loop {
            match queue.pop_up_to(target, self.max_wait.max(Duration::from_millis(1))) {
                Pop::Items(items) => break items,
                Pop::TimedOut => {
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        return Some(Vec::new());
                    }
                }
                Pop::Closed => return None,
            }
        };
        // the fill window never extends past the horizon
        let mut fill_deadline = Instant::now() + self.max_wait;
        if let Some(d) = deadline {
            fill_deadline = fill_deadline.min(d);
        }
        while batch.len() < target {
            let now = Instant::now();
            if now >= fill_deadline {
                break;
            }
            match queue.pop_up_to(target - batch.len(), fill_deadline - now) {
                Pop::Items(mut items) => batch.append(&mut items),
                // timeout or close: serve what we already hold
                Pop::TimedOut | Pop::Closed => break,
            }
        }
        Some(batch)
    }
}

/// The same closing rule in virtual time: should a batch be dispatched
/// now? (`oldest_wait_ns` is how long the front request has waited;
/// `closed` means no further arrivals can ever come.)
pub fn batch_ready(
    depth: usize,
    target: usize,
    oldest_wait_ns: u64,
    max_wait_ns: u64,
    closed: bool,
) -> bool {
    depth >= target.max(1) || (depth > 0 && (oldest_wait_ns >= max_wait_ns || closed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn full_batch_returns_immediately() {
        let q = BoundedQueue::bounded(16);
        for i in 0..8 {
            q.push(i).unwrap();
        }
        let b = Batcher::new(Duration::from_secs(5));
        let t0 = Instant::now();
        let batch = b.next_batch(&q, 8, None).unwrap();
        assert_eq!(batch, (0..8).collect::<Vec<_>>());
        assert!(t0.elapsed() < Duration::from_secs(1), "no max_wait stall on a full batch");
    }

    #[test]
    fn lone_request_released_by_timeout() {
        let q = BoundedQueue::bounded(16);
        q.push(42).unwrap();
        let b = Batcher::new(Duration::from_millis(30));
        let t0 = Instant::now();
        let batch = b.next_batch(&q, 64, None).unwrap();
        assert_eq!(batch, vec![42]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "honors max_wait, waited {waited:?}");
        assert!(waited < Duration::from_secs(2), "does not starve, waited {waited:?}");
    }

    #[test]
    fn closed_and_drained_returns_none() {
        let q = BoundedQueue::bounded(4);
        q.push(1).unwrap();
        q.close();
        let b = Batcher::new(Duration::from_millis(10));
        assert_eq!(b.next_batch(&q, 4, None), Some(vec![1]), "leftovers still served after close");
        assert_eq!(b.next_batch::<i32>(&q, 4, None), None);
    }

    #[test]
    fn batch_fills_from_concurrent_producer() {
        let q = BoundedQueue::bounded(64);
        let b = Batcher::new(Duration::from_millis(300));
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..4 {
                    q.push(i).unwrap();
                    std::thread::sleep(Duration::from_millis(5));
                }
            });
            let batch = b.next_batch(&q, 4, None).unwrap();
            assert_eq!(batch, vec![0, 1, 2, 3], "accumulates across pops until target");
        });
    }

    #[test]
    fn expired_deadline_refuses_to_open_under_trickle_load() {
        // regression: with requests always available, the old code never
        // hit Pop::TimedOut and so never noticed the horizon — it kept
        // opening batches forever
        let q = BoundedQueue::bounded(16);
        for i in 0..8 {
            q.push(i).unwrap();
        }
        let b = Batcher::new(Duration::from_millis(5));
        let past = Instant::now() - Duration::from_millis(1);
        assert_eq!(
            b.next_batch(&q, 4, Some(past)),
            Some(Vec::new()),
            "expired horizon must refuse to open a batch even with work queued"
        );
        assert_eq!(q.len(), 8, "no request consumed past the horizon");
    }

    #[test]
    fn fill_window_capped_at_deadline() {
        // regression: a batch opening just before the horizon must not
        // wait a full max_wait for fill — the window is clipped
        let q = BoundedQueue::bounded(16);
        q.push(1).unwrap();
        let b = Batcher::new(Duration::from_secs(5));
        let horizon = Instant::now() + Duration::from_millis(40);
        let t0 = Instant::now();
        let batch = b.next_batch(&q, 64, Some(horizon)).unwrap();
        assert_eq!(batch, vec![1]);
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_secs(2),
            "fill wait must be clipped at the horizon, waited {waited:?}"
        );
    }

    #[test]
    fn virtual_rule_matches_policy() {
        // full batch: ready regardless of waits
        assert!(batch_ready(8, 8, 0, 1000, false));
        // undersized, young: not ready
        assert!(!batch_ready(3, 8, 10, 1000, false));
        // undersized but the front request hit max_wait: ready
        assert!(batch_ready(3, 8, 1000, 1000, false));
        // undersized leftovers after close: ready
        assert!(batch_ready(3, 8, 0, 1000, true));
        // empty: never ready
        assert!(!batch_ready(0, 8, 0, 0, true));
        // target 0 normalizes to 1
        assert!(batch_ready(1, 0, 0, 1000, false));
    }
}
