//! `serve` — the adaptive micro-batching inference subsystem (DESIGN.md
//! §7): AdaBatch's "batch size is a control variable" thesis transplanted
//! to the request-serving path, where the measured signals are queue
//! depth and tail latency instead of gradient statistics.
//!
//! Pipeline: an open-loop load generator ([`loadgen`]) pushes requests
//! into a bounded condvar queue ([`queue`]); a batcher ([`batcher`])
//! drains them into micro-batches sized by a [`governor::ServeGovernor`]
//! (fixed / queue-depth-proportional / p99-SLO doubling-halving) and
//! padded to an eval-executable ladder rung; a worker pool ([`server`])
//! runs forward-only inference through the same
//! [`crate::runtime::ModelRuntime`] contract training uses. Per-request
//! latencies land in a log-bucketed [`crate::metrics::LatencyHistogram`]
//! and come out as a stable JSON report (`adabatch serve-bench`).
//!
//! Two clocks drive the same pipeline: **virtual** (a discrete-event
//! driver with a deterministic service-time model — bit-identical reports
//! given (seed, config), the serving twin of the trainer's determinism
//! contract) and **wall** (real scoped threads, real latencies, for
//! actual measurement).

pub mod batcher;
pub mod governor;
pub mod lifecycle;
pub mod loadgen;
pub mod queue;
pub mod server;

pub use batcher::{batch_ready, Batcher};
pub use governor::{
    pad_to_rung, serve_ladder, FixedServeGovernor, QueueDepthGovernor, ServeGovernor,
    ServeObservation, SloGovernor,
};
pub use lifecycle::{
    AdmissionPolicy, Control, FaultPlan, LifecycleConfig, LifecyclePlan, ReloadSpec, RetryPolicy,
};
pub use loadgen::{arrival_schedule, run_serve_bench, run_virtual, Clock, VirtualCfg};
pub use queue::{BoundedQueue, Pop, Reject};
pub use server::serve_wall;

use anyhow::Result;

use crate::coordinator::dataset::{GatherBufs, TrainData};
use crate::metrics::LatencyHistogram;
use crate::optim::param::ParamSet;
use crate::runtime::{Dtype, HostBatch, ModelRuntime, StepKind, StepOutputs, Workspace};

/// One inference request. The payload is an index into a shared sample
/// pool (requests reference data, they don't carry copies — the queue
/// stays cheap at any feature width).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// index into the bench's sample pool
    pub sample: usize,
    /// arrival time on the bench clock, ns since bench start
    pub arrival_ns: u64,
}

/// Aggregated outcome of one serving run, identical in shape for the
/// virtual and wall clocks.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    /// end-to-end request latencies (requests arriving during warmup are
    /// excluded, so reported tails are steady-state)
    pub hist: LatencyHistogram,
    /// requests served (warmup included)
    pub completed: u64,
    /// micro-batches dispatched
    pub batches: u64,
    /// Σ padded batch sizes actually executed
    pub padded_samples: u64,
    /// requests rejected at admission — the queue (or its virtual-clock
    /// mirror) was at capacity; open-loop arrivals are never delayed
    pub shed: u64,
    /// requests never served before the bench horizon (virtual clock)
    pub unserved: u64,
    /// Σ per-batch loss (inference checksum: proves the model really ran)
    pub loss_sum: f64,
    /// Σ per-batch correct-prediction counts
    pub correct_sum: f64,
    /// completion time of the last served batch, ns on the bench clock
    pub last_done_ns: u64,
    /// packed-weight rebuilds across all serve workers (params are frozen
    /// while serving, so this should stay at one per packed tensor per
    /// worker)
    pub pack_count: u64,
    /// steady-state bytes held by the workers' arenas
    pub alloc_bytes: u64,
    /// batch dispatches that failed and were requeued with backoff
    pub retries: u64,
    /// batch dispatches that failed (injected fault or worker panic)
    pub failed_batches: u64,
    /// queued requests evicted by the shed-oldest / deadline-aware
    /// admission policies to make room for newer arrivals
    pub evicted: u64,
    /// hot reloads applied (governor / SLO / ladder swap)
    pub reloads: u64,
    /// true when the run ended via graceful drain (admission closed,
    /// every accepted request served) rather than the horizon cutoff
    pub drained: bool,
}

/// The inference hot path both clocks share: gather `batch`'s samples
/// padded to `padded`, and run the forward-only eval executable through
/// the calling worker's long-lived arena (serve params are frozen, so
/// the packed-weight cache packs once per worker for the whole run).
pub(crate) fn forward_batch(
    rt: &ModelRuntime,
    params: &ParamSet,
    data: &TrainData,
    batch: &[Request],
    padded: usize,
    bufs: &mut GatherBufs,
    ws: &mut Workspace,
) -> Result<StepOutputs> {
    let idx: Vec<usize> = batch.iter().map(|r| r.sample).collect();
    data.gather(&idx, padded, bufs);
    let exe = rt.executable(StepKind::Eval, padded)?;
    let x = match data.x_dtype() {
        Dtype::F32 => HostBatch::F32(&bufs.x_f32),
        Dtype::I32 => HostBatch::I32(&bufs.x_i32),
    };
    exe.run(params, x, &bufs.y, ws)
}

impl ServeStats {
    /// Mean unpadded micro-batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }

    /// Completed requests per second of serving makespan.
    pub fn throughput_rps(&self) -> f64 {
        if self.last_done_ns == 0 {
            0.0
        } else {
            self.completed as f64 * 1e9 / self.last_done_ns as f64
        }
    }
}
