//! [`ServeGovernor`] — the micro-batch criterion for the inference path,
//! mirroring [`crate::schedule::BatchGovernor`] on the training side.
//!
//! AdaBatch's thesis is that batch size is a control variable, not a
//! constant; on the serving side the measured signals are **queue depth**
//! (throughput pressure) and **tail latency** (the user-facing cost of
//! batching). Three criteria plug into the same batcher/server loop:
//!
//! * [`FixedServeGovernor`] — the static baseline every adaptive arm is
//!   judged against.
//! * [`QueueDepthGovernor`] — proportional control: serve the smallest
//!   ladder rung that covers the current backlog.
//! * [`SloGovernor`] — AdaBatch-style doubling/halving driven by a
//!   p99-latency SLO: over a fixed decision window it compares measured
//!   p99 against the SLO and *disambiguates the breach by queue depth* —
//!   a breach with a deep queue is an overload (double the batch: more
//!   throughput per dispatch), a breach with a shallow queue is
//!   over-batching (halve: requests are waiting on fill, not capacity).
//!   With headroom (p99 < SLO/2) and a standing backlog it also grows.
//!
//! Contract notes (mirroring the training trait): `target_batch` is
//! consulted once per drain; `observe` receives every completed batch's
//! per-request latencies; `ladder` must enumerate every size the governor
//! can ever request so the runtime's eval-executable ladder can be built
//! up front (the serving twin of pre-flight planning).

use crate::metrics::LatencyHistogram;

/// One completed micro-batch's measurements, fed back to the governor.
#[derive(Debug)]
pub struct ServeObservation<'a> {
    /// requests actually in the batch (before padding)
    pub batch: usize,
    /// queue depth right after this batch was drained
    pub queue_depth: usize,
    /// end-to-end latency of each request in the batch, ns
    pub latencies_ns: &'a [u64],
}

/// A micro-batch criterion driving the serving loop.
pub trait ServeGovernor: Send {
    /// Display name (report label).
    fn name(&self) -> &str;

    /// Target size for the next micro-batch, given the current backlog.
    fn target_batch(&mut self, queue_depth: usize) -> usize;

    /// Feed one completed batch's measurements.
    fn observe(&mut self, _obs: ServeObservation<'_>) {}

    /// Every batch size this governor may ever request (ascending).
    fn ladder(&self) -> Vec<usize>;

    /// Size the governor is currently steering toward.
    fn current_batch(&self) -> usize;

    /// Adaptation decisions taken so far (0 for static criteria).
    fn decisions(&self) -> usize {
        0
    }
}

/// Geometric ×2 rungs from `min_batch` up to `max_batch` (inclusive when
/// reachable; always contains `min_batch`).
pub fn serve_ladder(min_batch: usize, max_batch: usize) -> Vec<usize> {
    assert!(min_batch >= 1, "min batch must be ≥ 1");
    let mut out = vec![min_batch];
    let mut r = min_batch;
    while r.saturating_mul(2) <= max_batch {
        r *= 2;
        out.push(r);
    }
    out
}

/// Smallest rung ≥ `k` from an ascending ladder (the largest rung when
/// `k` exceeds them all) — the padding target for a drained batch.
pub fn pad_to_rung(k: usize, ladder: &[usize]) -> usize {
    assert!(!ladder.is_empty(), "empty batch ladder");
    for &r in ladder {
        if r >= k {
            return r;
        }
    }
    *ladder.last().unwrap()
}

/// Static micro-batch size — the baseline arm.
#[derive(Debug, Clone)]
pub struct FixedServeGovernor {
    name: String,
    batch: usize,
}

impl FixedServeGovernor {
    pub fn new(batch: usize) -> Self {
        assert!(batch >= 1);
        FixedServeGovernor { name: format!("fixed-{batch}"), batch }
    }
}

impl ServeGovernor for FixedServeGovernor {
    fn name(&self) -> &str {
        &self.name
    }

    fn target_batch(&mut self, _queue_depth: usize) -> usize {
        self.batch
    }

    fn ladder(&self) -> Vec<usize> {
        vec![self.batch]
    }

    fn current_batch(&self) -> usize {
        self.batch
    }
}

/// Backlog-proportional criterion: the smallest ladder rung covering the
/// current queue depth, clamped to [min, max].
#[derive(Debug, Clone)]
pub struct QueueDepthGovernor {
    name: String,
    min_batch: usize,
    max_batch: usize,
    current: usize,
    decisions: usize,
}

impl QueueDepthGovernor {
    pub fn new(min_batch: usize, max_batch: usize) -> Self {
        assert!(min_batch >= 1 && max_batch >= min_batch);
        QueueDepthGovernor {
            name: "queue-depth".to_string(),
            min_batch,
            max_batch,
            current: min_batch,
            decisions: 0,
        }
    }
}

impl ServeGovernor for QueueDepthGovernor {
    fn name(&self) -> &str {
        &self.name
    }

    fn target_batch(&mut self, queue_depth: usize) -> usize {
        let mut b = self.min_batch;
        while b < self.max_batch && b < queue_depth {
            b *= 2;
        }
        if b != self.current {
            self.current = b;
            self.decisions += 1;
        }
        b
    }

    fn ladder(&self) -> Vec<usize> {
        serve_ladder(self.min_batch, self.max_batch)
    }

    fn current_batch(&self) -> usize {
        self.current
    }

    fn decisions(&self) -> usize {
        self.decisions
    }
}

/// AdaBatch-style doubling/halving driven by a p99-latency SLO.
#[derive(Debug, Clone)]
pub struct SloGovernor {
    name: String,
    /// the p99 objective, ns
    pub slo_ns: u64,
    pub min_batch: usize,
    pub max_batch: usize,
    /// requests aggregated per doubling/halving decision
    pub window: usize,
    current: usize,
    seen: usize,
    hist: LatencyHistogram,
    decisions: usize,
}

impl SloGovernor {
    pub fn new(slo_ns: u64, min_batch: usize, max_batch: usize, window: usize) -> Self {
        assert!(slo_ns > 0, "SLO must be positive");
        assert!(min_batch >= 1 && max_batch >= min_batch);
        assert!(window >= 1);
        SloGovernor {
            name: "slo-adaptive".to_string(),
            slo_ns,
            min_batch,
            max_batch,
            window,
            current: min_batch,
            seen: 0,
            hist: LatencyHistogram::new(),
            decisions: 0,
        }
    }
}

impl ServeGovernor for SloGovernor {
    fn name(&self) -> &str {
        &self.name
    }

    fn target_batch(&mut self, _queue_depth: usize) -> usize {
        self.current
    }

    fn observe(&mut self, obs: ServeObservation<'_>) {
        for &l in obs.latencies_ns {
            self.hist.record(l);
        }
        self.seen += obs.latencies_ns.len();
        if self.seen < self.window {
            return;
        }
        let p99 = self.hist.p99();
        let prev = self.current;
        if p99 > self.slo_ns {
            if obs.queue_depth > self.current {
                // breach under backlog: overloaded — buy throughput
                self.current = (self.current * 2).min(self.max_batch);
            } else {
                // breach with an idle queue: over-batching — cut fill wait
                self.current = (self.current / 2).max(self.min_batch);
            }
        } else if p99.saturating_mul(2) < self.slo_ns && obs.queue_depth > self.current {
            // latency headroom and a standing backlog: grow
            self.current = (self.current * 2).min(self.max_batch);
        }
        if self.current != prev {
            self.decisions += 1;
        }
        self.seen = 0;
        self.hist = LatencyHistogram::new();
    }

    fn ladder(&self) -> Vec<usize> {
        serve_ladder(self.min_batch, self.max_batch)
    }

    fn current_batch(&self) -> usize {
        self.current
    }

    fn decisions(&self) -> usize {
        self.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(batch: usize, depth: usize, lats: &[u64]) -> ServeObservation<'_> {
        ServeObservation { batch, queue_depth: depth, latencies_ns: lats }
    }

    #[test]
    fn ladder_and_padding() {
        assert_eq!(serve_ladder(1, 8), vec![1, 2, 4, 8]);
        assert_eq!(serve_ladder(4, 4), vec![4]);
        assert_eq!(serve_ladder(2, 7), vec![2, 4]);
        let l = serve_ladder(1, 16);
        assert_eq!(pad_to_rung(1, &l), 1);
        assert_eq!(pad_to_rung(3, &l), 4);
        assert_eq!(pad_to_rung(16, &l), 16);
        assert_eq!(pad_to_rung(99, &l), 16);
    }

    #[test]
    fn ladder_reachability_property() {
        // Pin the exact contract ServeConfig::validate enforces: the
        // ladder tops out at max_batch iff max_batch = min·2^k; when it
        // does not, pad_to_rung pads oversize drains *down* — which is
        // why unreachable configurations must be rejected upstream.
        for min in 1usize..=24 {
            for max in min..=96 {
                let ladder = serve_ladder(min, max);
                // structural invariants, all (min, max)
                assert_eq!(ladder[0], min);
                assert!(ladder.windows(2).all(|w| w[1] == w[0] * 2), "geometric ×2");
                assert!(ladder.iter().all(|&r| r <= max), "no rung exceeds max");

                let reachable = {
                    let mut r = min;
                    while r < max {
                        r *= 2;
                    }
                    r == max
                };
                assert_eq!(
                    *ladder.last().unwrap() == max,
                    reachable,
                    "ladder({min},{max}) reaches max iff max = min·2^k"
                );

                // padding: any k within the ladder's reach pads *up*...
                let top = *ladder.last().unwrap();
                for k in 1..=top {
                    assert!(pad_to_rung(k, &ladder) >= k);
                }
                // ...but a drain larger than every rung pads DOWN — the
                // failure mode unreachable max_batch would expose
                assert_eq!(pad_to_rung(top + 1, &ladder), top);
            }
        }
        // the motivating example from the issue: min=5, max=8 → [5]
        assert_eq!(serve_ladder(5, 8), vec![5]);
        assert_eq!(pad_to_rung(8, &serve_ladder(5, 8)), 5, "oversize drain padded down");
    }

    #[test]
    fn unreachable_max_batch_rejected_by_config() {
        use crate::config::ServeConfig;
        let ok = ServeConfig::default();
        ok.validate().unwrap();
        let mut bad = ServeConfig::default();
        bad.min_batch = 5;
        bad.max_batch = 8;
        let err = bad.validate().unwrap_err().to_string();
        // rejected (today by the power-of-two rule; the reachability
        // check keeps holding if that rule is ever relaxed)
        assert!(!err.is_empty());
    }

    #[test]
    fn fixed_is_constant() {
        let mut g = FixedServeGovernor::new(8);
        assert_eq!(g.name(), "fixed-8");
        assert_eq!(g.target_batch(0), 8);
        assert_eq!(g.target_batch(10_000), 8);
        assert_eq!(g.ladder(), vec![8]);
        assert_eq!(g.decisions(), 0);
    }

    #[test]
    fn queue_depth_tracks_backlog() {
        let mut g = QueueDepthGovernor::new(1, 16);
        assert_eq!(g.target_batch(0), 1);
        assert_eq!(g.target_batch(3), 4);
        assert_eq!(g.target_batch(16), 16);
        assert_eq!(g.target_batch(500), 16, "clamped at max");
        assert_eq!(g.target_batch(0), 1, "shrinks when the backlog clears");
        assert!(g.decisions() > 0);
        assert_eq!(g.ladder(), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn slo_doubles_under_overload_breach() {
        let mut g = SloGovernor::new(1_000_000, 1, 8, 4);
        // p99 over the window ≈ 5ms > 1ms SLO, with a deep queue
        let lats = [5_000_000u64, 5_000_000, 5_000_000, 5_000_000];
        g.observe(obs(4, 100, &lats));
        assert_eq!(g.current_batch(), 2);
        g.observe(obs(4, 100, &lats));
        g.observe(obs(4, 100, &lats));
        g.observe(obs(4, 100, &lats));
        assert_eq!(g.current_batch(), 8, "clamped at max after repeated breaches");
        assert_eq!(g.decisions(), 3);
    }

    #[test]
    fn slo_halves_on_overbatching_breach() {
        let mut g = SloGovernor::new(1_000_000, 1, 16, 4);
        // climb to 4 first
        let slow = [5_000_000u64; 4];
        g.observe(obs(4, 100, &slow));
        g.observe(obs(4, 100, &slow));
        assert_eq!(g.current_batch(), 4);
        // breach with a *shallow* queue: fill wait dominates — halve
        g.observe(obs(4, 0, &slow));
        assert_eq!(g.current_batch(), 2);
        g.observe(obs(4, 0, &slow));
        g.observe(obs(4, 0, &slow));
        assert_eq!(g.current_batch(), 1, "clamped at min");
    }

    #[test]
    fn slo_grows_on_headroom_with_backlog_only() {
        let mut g = SloGovernor::new(10_000_000, 1, 8, 2);
        let fast = [1_000_000u64, 1_000_000]; // p99 ≈ 1ms ≪ 10ms SLO
        g.observe(obs(2, 0, &fast));
        assert_eq!(g.current_batch(), 1, "no backlog: no reason to batch more");
        g.observe(obs(2, 50, &fast));
        assert_eq!(g.current_batch(), 2, "headroom + backlog: grow");
    }

    #[test]
    fn slo_window_gates_decisions() {
        let mut g = SloGovernor::new(1_000_000, 1, 8, 10);
        let slow = [5_000_000u64; 4];
        g.observe(obs(4, 100, &slow));
        g.observe(obs(4, 100, &slow));
        assert_eq!(g.current_batch(), 1, "window (10) not yet full at 8 seen");
        g.observe(obs(4, 100, &slow));
        assert_eq!(g.current_batch(), 2, "12 ≥ 10: decision fires");
    }

    #[test]
    fn governors_are_object_safe() {
        let mut govs: Vec<Box<dyn ServeGovernor>> = vec![
            Box::new(FixedServeGovernor::new(4)),
            Box::new(QueueDepthGovernor::new(1, 32)),
            Box::new(SloGovernor::new(25_000_000, 1, 32, 64)),
        ];
        for g in govs.iter_mut() {
            let t = g.target_batch(5);
            assert!(t >= 1);
            assert!(g.ladder().contains(&g.current_batch()));
            g.observe(obs(2, 0, &[1000, 2000]));
        }
    }
}
