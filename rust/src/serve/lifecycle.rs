//! Serving lifecycle: admission policies, retry with backoff, fault
//! injection, and control-plane messages (drain / suspend / resume /
//! hot reload).
//!
//! The lifecycle layer turns the serving path from a benchmark rig into
//! a daemon. Everything here is deterministic by construction: fault
//! injection is a pure function of (fault seed, batch sequence number,
//! attempt), backoff delays are fixed arithmetic on the virtual clock,
//! and control events fire at configured virtual timestamps. See
//! DESIGN.md §13 for the state machine and the determinism contract.

use anyhow::{bail, Result};

use crate::config::ServeConfig;
use crate::serve::governor::{
    serve_ladder, FixedServeGovernor, QueueDepthGovernor, ServeGovernor, SloGovernor,
};
use crate::util::rng::Pcg32;

/// How the server admits (or refuses) an arriving request when the
/// bounded queue is full.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// Block the producer until space frees up (bounded by the bench
    /// deadline on the wall clock; never sheds on the virtual clock).
    Block,
    /// Reject the arriving request (classic tail-drop). The default,
    /// and the historical behavior of the wall-clock load generator.
    ShedNewest,
    /// Evict the oldest queued request to make room for the new one
    /// (head-drop: freshest traffic wins).
    ShedOldest,
    /// Evict queued requests whose age already exceeds `deadline_ns`
    /// (they could not meet the SLO anyway); if none are expired,
    /// shed the newcomer.
    DeadlineAware { deadline_ns: u64 },
}

impl AdmissionPolicy {
    pub fn from_name(name: &str, deadline_ns: u64) -> Result<Self> {
        match name {
            "block" => Ok(AdmissionPolicy::Block),
            "shed-newest" => Ok(AdmissionPolicy::ShedNewest),
            "shed-oldest" => Ok(AdmissionPolicy::ShedOldest),
            "deadline" | "deadline-aware" => {
                if deadline_ns == 0 {
                    bail!("admission policy 'deadline' requires --admission-deadline-ms > 0");
                }
                Ok(AdmissionPolicy::DeadlineAware { deadline_ns })
            }
            other => bail!(
                "unknown admission policy {other:?} (expected block|shed-newest|shed-oldest|deadline)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::ShedNewest => "shed-newest",
            AdmissionPolicy::ShedOldest => "shed-oldest",
            AdmissionPolicy::DeadlineAware { .. } => "deadline",
        }
    }
}

/// Per-batch retry policy: a failed batch is requeued with exponential
/// backoff until `budget` attempts have been spent, at which point the
/// failure surfaces loudly as a run error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Maximum number of attempts per batch (>= 1). An attempt is one
    /// dispatch to a worker; budget 3 means the original try plus two
    /// retries.
    pub budget: u32,
    /// Base backoff delay; attempt `a` (1-based, counting the failed
    /// attempt) waits `backoff_ns << (a-1)`, capped to avoid overflow.
    pub backoff_ns: u64,
}

impl RetryPolicy {
    /// Delay before re-dispatching after `failed_attempts` attempts
    /// have failed (so 1 after the first failure).
    pub fn backoff_for(&self, failed_attempts: u32) -> u64 {
        let shift = failed_attempts.saturating_sub(1).min(16);
        self.backoff_ns.saturating_mul(1u64 << shift)
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            budget: 3,
            backoff_ns: 1_000_000, // 1 ms
        }
    }
}

/// Deterministic fault plan: whether a given (batch, attempt) pair
/// fails is a pure function of the plan seed and the batch's sequence
/// number, so a (seed, config, fault plan) triple replays exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability that a batch's first `fail_attempts` dispatches fail.
    pub rate: f64,
    /// How many leading attempts of a selected batch fail. 1 means the
    /// retry succeeds; `u32::MAX` exhausts any finite budget (used by
    /// the budget-exhaustion tests).
    pub fail_attempts: u32,
    /// On the wall clock, panic inside the worker instead of returning
    /// an error — exercises the catch_unwind path.
    pub panic: bool,
}

impl FaultPlan {
    pub fn should_fail(&self, batch_seq: u64, attempt: u32) -> bool {
        if self.rate <= 0.0 || attempt > self.fail_attempts {
            return false;
        }
        // One draw per batch: mix the sequence number into the seed so
        // each batch gets an independent, replayable coin flip.
        let mut rng = Pcg32::new(self.seed ^ batch_seq.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        rng.next_f64() < self.rate
    }
}

/// Control-plane message for a running wall-clock server.
#[derive(Debug, Clone)]
pub enum Control {
    /// Close admission, serve every accepted request, then shut down.
    Drain,
    /// Park the worker pool (workspaces stay warm); queued requests wait.
    Suspend,
    /// Wake a suspended pool.
    Resume,
    /// Swap SLO target / governor / ladder bounds without dropping
    /// in-flight requests.
    Reload(ReloadSpec),
}

/// The reconfiguration applied by a hot reload.
#[derive(Debug, Clone, PartialEq)]
pub struct ReloadSpec {
    pub governor: String,
    pub slo_ms: f64,
    pub min_batch: usize,
    pub max_batch: usize,
    pub window: usize,
}

impl ReloadSpec {
    pub fn validate(&self) -> Result<()> {
        if !self.min_batch.is_power_of_two() || !self.max_batch.is_power_of_two() {
            bail!("reload: min_batch and max_batch must be powers of two");
        }
        if self.min_batch > self.max_batch {
            bail!("reload: min_batch must be <= max_batch");
        }
        if self.slo_ms <= 0.0 {
            bail!("reload: slo_ms must be positive");
        }
        if self.window == 0 {
            bail!("reload: window must be >= 1");
        }
        let ladder = serve_ladder(self.min_batch, self.max_batch);
        if *ladder.last().expect("ladder is never empty") != self.max_batch {
            bail!(
                "reload: max_batch {} is not reachable from min_batch {} by doubling",
                self.max_batch,
                self.min_batch
            );
        }
        match self.governor.as_str() {
            "fixed" | "queue" | "slo" => Ok(()),
            other => bail!("reload: unknown governor {other:?} (expected fixed|queue|slo)"),
        }
    }

    pub fn ladder(&self) -> Vec<usize> {
        serve_ladder(self.min_batch, self.max_batch)
    }

    pub fn build_governor(&self) -> Result<Box<dyn ServeGovernor>> {
        let slo_ns = (self.slo_ms * 1e6) as u64;
        match self.governor.as_str() {
            "fixed" => Ok(Box::new(FixedServeGovernor::new(self.max_batch))),
            "queue" => Ok(Box::new(QueueDepthGovernor::new(
                self.min_batch,
                self.max_batch,
            ))),
            "slo" => Ok(Box::new(SloGovernor::new(
                slo_ns,
                self.min_batch,
                self.max_batch,
                self.window,
            ))),
            other => bail!("reload: unknown governor {other:?} (expected fixed|queue|slo)"),
        }
    }
}

/// Lifecycle knobs as they appear on `ServeConfig` (human units; the
/// ns-resolved form is [`LifecyclePlan`]).
#[derive(Debug, Clone, PartialEq)]
pub struct LifecycleConfig {
    /// Admission policy name: block | shed-newest | shed-oldest | deadline.
    pub admission: String,
    /// Age bound for the deadline-aware policy (ms).
    pub admission_deadline_ms: f64,
    /// Max dispatch attempts per batch.
    pub retry_budget: u32,
    /// Base retry backoff (ms), doubled per failed attempt.
    pub retry_backoff_ms: f64,
    /// Probability a batch is selected by the fault plan (0 disables).
    pub fault_rate: f64,
    /// Seed for the fault plan's per-batch coin flips.
    pub fault_seed: u64,
    /// How many leading attempts of a selected batch fail.
    pub fault_attempts: u32,
    /// Wall clock only: panic in the worker instead of returning Err.
    pub fault_panic: bool,
    /// Virtual seconds at which admission closes for a graceful drain
    /// (None = classic horizon cutoff).
    pub drain_at_s: Option<f64>,
    /// Suspend the worker pool at this virtual time...
    pub suspend_at_s: Option<f64>,
    /// ...and resume it at this one (required together).
    pub resume_at_s: Option<f64>,
    /// Apply `reload` at this virtual time.
    pub reload_at_s: Option<f64>,
    pub reload: Option<ReloadSpec>,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            admission: "shed-newest".to_string(),
            admission_deadline_ms: 0.0,
            retry_budget: 3,
            retry_backoff_ms: 1.0,
            fault_rate: 0.0,
            fault_seed: 0,
            fault_attempts: 1,
            fault_panic: false,
            drain_at_s: None,
            suspend_at_s: None,
            resume_at_s: None,
            reload_at_s: None,
            reload: None,
        }
    }
}

impl LifecycleConfig {
    pub fn validate(&self) -> Result<()> {
        AdmissionPolicy::from_name(&self.admission, (self.admission_deadline_ms * 1e6) as u64)?;
        if self.retry_budget == 0 {
            bail!("retry_budget must be >= 1");
        }
        if self.retry_backoff_ms < 0.0 {
            bail!("retry_backoff_ms must be >= 0");
        }
        if !(0.0..=1.0).contains(&self.fault_rate) {
            bail!("fault_rate must be in [0, 1]");
        }
        if self.fault_rate > 0.0 && self.fault_attempts == 0 {
            bail!("fault_attempts must be >= 1 when fault_rate > 0");
        }
        match (self.suspend_at_s, self.resume_at_s) {
            (None, None) => {}
            (Some(s), Some(r)) => {
                if r <= s {
                    bail!("resume_at must be after suspend_at");
                }
            }
            _ => bail!("suspend_at and resume_at must be given together"),
        }
        match (self.reload_at_s, &self.reload) {
            (None, None) => {}
            (Some(_), Some(spec)) => spec.validate()?,
            (Some(_), None) => bail!("reload_at given without a reload spec"),
            (None, Some(_)) => bail!("reload spec given without --reload-at"),
        }
        Ok(())
    }
}

/// The ns-resolved lifecycle plan the drivers execute.
#[derive(Debug, Clone)]
pub struct LifecyclePlan {
    pub admission: AdmissionPolicy,
    pub retry: RetryPolicy,
    pub fault: Option<FaultPlan>,
    /// Virtual timestamp after which no new arrivals are admitted; the
    /// driver then serves everything accepted and shuts down.
    pub drain_at_ns: Option<u64>,
    /// (suspend, resume) virtual timestamps.
    pub suspend_ns: Option<(u64, u64)>,
    /// (at, spec) for the hot reload.
    pub reload: Option<(u64, ReloadSpec)>,
}

impl Default for LifecyclePlan {
    fn default() -> Self {
        LifecyclePlan {
            admission: AdmissionPolicy::ShedNewest,
            retry: RetryPolicy::default(),
            fault: None,
            drain_at_ns: None,
            suspend_ns: None,
            reload: None,
        }
    }
}

impl LifecyclePlan {
    pub fn from_serve(scfg: &ServeConfig) -> Result<Self> {
        let lc = &scfg.lifecycle;
        let admission =
            AdmissionPolicy::from_name(&lc.admission, (lc.admission_deadline_ms * 1e6) as u64)?;
        let retry = RetryPolicy {
            budget: lc.retry_budget,
            backoff_ns: (lc.retry_backoff_ms * 1e6) as u64,
        };
        let fault = if lc.fault_rate > 0.0 {
            Some(FaultPlan {
                seed: lc.fault_seed,
                rate: lc.fault_rate,
                fail_attempts: lc.fault_attempts,
                panic: lc.fault_panic,
            })
        } else {
            None
        };
        Ok(LifecyclePlan {
            admission,
            retry,
            fault,
            drain_at_ns: lc.drain_at_s.map(|s| (s * 1e9) as u64),
            suspend_ns: match (lc.suspend_at_s, lc.resume_at_s) {
                (Some(s), Some(r)) => Some(((s * 1e9) as u64, (r * 1e9) as u64)),
                _ => None,
            },
            reload: match (lc.reload_at_s, &lc.reload) {
                (Some(at), Some(spec)) => Some(((at * 1e9) as u64, spec.clone())),
                _ => None,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_parse_round_trip() {
        for name in ["block", "shed-newest", "shed-oldest"] {
            let p = AdmissionPolicy::from_name(name, 0).unwrap();
            assert_eq!(p.name(), name);
        }
        let p = AdmissionPolicy::from_name("deadline", 5_000_000).unwrap();
        assert_eq!(p, AdmissionPolicy::DeadlineAware { deadline_ns: 5_000_000 });
        assert!(AdmissionPolicy::from_name("deadline", 0).is_err());
        assert!(AdmissionPolicy::from_name("lru", 0).is_err());
    }

    #[test]
    fn backoff_doubles_and_saturates() {
        let r = RetryPolicy { budget: 5, backoff_ns: 1_000 };
        assert_eq!(r.backoff_for(1), 1_000);
        assert_eq!(r.backoff_for(2), 2_000);
        assert_eq!(r.backoff_for(3), 4_000);
        // Shift is clamped; huge attempt counts must not overflow.
        assert_eq!(r.backoff_for(1_000), 1_000 << 16);
    }

    #[test]
    fn fault_plan_is_deterministic_and_attempt_bounded() {
        let plan = FaultPlan { seed: 42, rate: 0.5, fail_attempts: 2, panic: false };
        for seq in 0..64u64 {
            let a = plan.should_fail(seq, 1);
            let b = plan.should_fail(seq, 1);
            assert_eq!(a, b, "same (seq, attempt) must replay identically");
            if a {
                assert!(plan.should_fail(seq, 2));
                assert!(!plan.should_fail(seq, 3), "attempts past fail_attempts succeed");
            }
        }
        let never = FaultPlan { seed: 42, rate: 0.0, fail_attempts: 1, panic: false };
        assert!(!never.should_fail(7, 1));
    }

    #[test]
    fn fault_plan_rate_one_selects_everything() {
        let plan = FaultPlan { seed: 9, rate: 1.0, fail_attempts: u32::MAX, panic: false };
        for seq in 0..16u64 {
            assert!(plan.should_fail(seq, 1));
            assert!(plan.should_fail(seq, 1_000_000));
        }
    }

    #[test]
    fn reload_spec_validation() {
        let good = ReloadSpec {
            governor: "slo".into(),
            slo_ms: 10.0,
            min_batch: 2,
            max_batch: 8,
            window: 32,
        };
        good.validate().unwrap();
        assert_eq!(good.ladder(), vec![2, 4, 8]);
        assert!(good.build_governor().is_ok());

        let bad_gov = ReloadSpec { governor: "pid".into(), ..good.clone() };
        assert!(bad_gov.validate().is_err());
        let bad_batch = ReloadSpec { min_batch: 3, ..good.clone() };
        assert!(bad_batch.validate().is_err());
        let bad_order = ReloadSpec { min_batch: 16, max_batch: 8, ..good };
        assert!(bad_order.validate().is_err());
    }

    #[test]
    fn lifecycle_config_validation() {
        let mut lc = LifecycleConfig::default();
        lc.validate().unwrap();

        lc.retry_budget = 0;
        assert!(lc.validate().is_err());
        lc.retry_budget = 3;

        lc.fault_rate = 1.5;
        assert!(lc.validate().is_err());
        lc.fault_rate = 0.0;

        lc.suspend_at_s = Some(1.0);
        assert!(lc.validate().is_err(), "suspend without resume");
        lc.resume_at_s = Some(0.5);
        assert!(lc.validate().is_err(), "resume before suspend");
        lc.resume_at_s = Some(2.0);
        lc.validate().unwrap();

        lc.reload_at_s = Some(1.0);
        assert!(lc.validate().is_err(), "reload_at without spec");
        lc.reload = Some(ReloadSpec {
            governor: "queue".into(),
            slo_ms: 10.0,
            min_batch: 1,
            max_batch: 4,
            window: 16,
        });
        lc.validate().unwrap();
    }

    #[test]
    fn plan_resolution_converts_units() {
        let mut scfg = ServeConfig::default();
        scfg.lifecycle.admission = "deadline".into();
        scfg.lifecycle.admission_deadline_ms = 2.0;
        scfg.lifecycle.retry_backoff_ms = 0.5;
        scfg.lifecycle.drain_at_s = Some(1.5);
        let plan = LifecyclePlan::from_serve(&scfg).unwrap();
        assert_eq!(
            plan.admission,
            AdmissionPolicy::DeadlineAware { deadline_ns: 2_000_000 }
        );
        assert_eq!(plan.retry.backoff_ns, 500_000);
        assert_eq!(plan.drain_at_ns, Some(1_500_000_000));
        assert!(plan.fault.is_none());
    }
}
