//! The wall-clock serving pipeline: batcher/dispatcher + a scoped-thread
//! worker pool running forward-only inference.
//!
//! Reuses the [`crate::coordinator::engine`] idioms: persistent workers
//! fed jobs over per-worker channels, worker-indexed results, and panic
//! liveness — if a worker dies mid-batch the dispatcher surfaces an
//! error instead of hanging (a finished worker owing a reply is a panic;
//! a finished worker with nothing in flight just processed its
//! `Finish`). Unlike the training engine there is **no barrier**: the
//! dispatcher streams batches to the least-loaded worker and folds
//! completions back in whenever they arrive, because serving cares about
//! per-request latency, not synchronous updates.
//!
//! The dispatcher owns the [`ServeGovernor`]: it consults
//! `target_batch(queue depth)` before each drain and feeds every
//! completed batch's latencies back via `observe`, closing the control
//! loop that makes the micro-batch size adaptive.
//!
//! Daemon lifecycle (DESIGN.md §13): a failed or panicked batch is
//! caught at the worker, reported as a [`WorkerReply::Failed`], and
//! requeued with exponential backoff until the retry budget is spent —
//! only budget exhaustion surfaces as an error. A [`Control`] channel
//! lets the caller drain (close admission, serve everything accepted),
//! suspend/resume dispatch without discarding warm workspaces, or hot
//! reload the governor and padding ladder mid-run.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::Batcher;
use super::governor::{pad_to_rung, ServeGovernor, ServeObservation};
use super::lifecycle::{Control, FaultPlan, LifecyclePlan, RetryPolicy};
use super::queue::BoundedQueue;
use super::{Request, ServeStats};
use crate::coordinator::dataset::{GatherBufs, TrainData};
use crate::optim::param::ParamSet;
use crate::runtime::{ModelRuntime, Workspace, WorkspaceStats};

enum Job {
    Run {
        /// queue depth right after this batch was drained
        depth: usize,
        batch: Vec<Request>,
        padded: usize,
        /// 1-based attempt counter; retries re-dispatch with attempt + 1
        attempt: u32,
        /// batch sequence number assigned at first dispatch — the fault
        /// plan keys on it so retries of one batch replay deterministically
        seq: u64,
    },
    Finish,
}

struct BatchDone {
    depth: usize,
    unpadded: usize,
    padded: usize,
    latencies_ns: Vec<u64>,
    /// per-request arrival times, aligned with `latencies_ns` (warmup
    /// filtering is per request, not per batch)
    arrivals_ns: Vec<u64>,
    loss: f64,
    correct: f64,
    done_ns: u64,
}

enum WorkerReply {
    Done(BatchDone),
    /// The batch failed (forward error, injected fault, or caught
    /// panic); the requests ride back so the dispatcher can requeue them.
    Failed { depth: usize, batch: Vec<Request>, attempt: u32, seq: u64, err: String },
}

/// A failed batch waiting out its backoff before re-dispatch.
struct RetryEntry {
    ready: Instant,
    depth: usize,
    batch: Vec<Request>,
    /// attempt number the *next* dispatch will carry
    attempt: u32,
    seq: u64,
}

/// Run the serving pipeline against `queue` until it is closed and
/// drained, or the bench `deadline` (the horizon) passes — whichever
/// comes first; at the deadline, still-queued requests are counted as
/// `unserved`, mirroring the virtual clock's horizon cutoff. Blocks the
/// calling thread (run it under `std::thread::scope` beside the load
/// generator). `start` anchors the bench clock that request `arrival_ns`
/// values were stamped against; requests arriving before `warmup_ns` are
/// served but excluded from the latency histogram.
///
/// `plan` carries the retry policy and optional fault plan; `control`,
/// when present, delivers [`Control`] messages (drain disables the
/// deadline: every accepted request is served). In-flight batches and
/// pending retries are always served to completion — accepted work is
/// never dropped, even past the horizon.
#[allow(clippy::too_many_arguments)]
pub fn serve_wall(
    rt: &ModelRuntime,
    params: &ParamSet,
    data: &TrainData,
    governor: &mut Box<dyn ServeGovernor>,
    queue: &BoundedQueue<Request>,
    workers: usize,
    kernel_threads: usize,
    max_wait: Duration,
    ladder: &[usize],
    start: Instant,
    warmup_ns: u64,
    deadline: Instant,
    plan: &LifecyclePlan,
    control: Option<Receiver<Control>>,
) -> Result<ServeStats> {
    assert!(workers > 0, "server needs at least one worker");
    assert!(kernel_threads > 0, "server needs at least one kernel thread");
    std::thread::scope(|scope| {
        let (res_tx, res_rx) = channel::<(usize, WorkerReply)>();
        let mut job_txs: Vec<Sender<Job>> = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        let fault = plan.fault;
        for w in 0..workers {
            let (tx, rx) = channel::<Job>();
            let res_tx = res_tx.clone();
            handles.push(scope.spawn(move || {
                worker_loop(w, rx, res_tx, rt, params, data, start, kernel_threads, fault)
            }));
            job_txs.push(tx);
        }
        drop(res_tx);

        let batcher = Batcher::new(max_wait);
        let mut stats = ServeStats::default();
        let mut in_flight = vec![0usize; workers];
        let mut retry_buf: Vec<RetryEntry> = Vec::new();
        let mut batch_seq = 0u64;
        let mut pad_ladder = ladder.to_vec();
        let mut draining = false;
        let mut suspended = false;

        let outcome = (|| -> Result<()> {
            loop {
                // control plane first: drain/suspend/resume/reload take
                // effect before the next dispatch decision
                if let Some(rx) = &control {
                    while let Ok(msg) = rx.try_recv() {
                        match msg {
                            Control::Drain => {
                                draining = true;
                                stats.drained = true;
                                queue.close();
                            }
                            Control::Suspend => suspended = true,
                            Control::Resume => suspended = false,
                            Control::Reload(spec) => {
                                *governor = spec.build_governor()?;
                                pad_ladder = spec.ladder();
                                stats.reloads += 1;
                            }
                        }
                    }
                }
                // fold in any completions that have landed (non-blocking)
                while let Ok((w, reply)) = res_rx.try_recv() {
                    in_flight[w] -= 1;
                    fold_reply(
                        &mut stats,
                        governor.as_mut(),
                        &mut retry_buf,
                        plan.retry,
                        warmup_ns,
                        reply,
                    )?;
                }
                if suspended {
                    // parked: workers keep their warm workspaces, nothing
                    // dispatches. A passed horizon (outside drain mode)
                    // overrides a lost Resume so the bench cannot hang.
                    if draining || Instant::now() < deadline {
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    }
                    suspended = false;
                }
                // due retries dispatch ahead of new batches: their
                // requests have been waiting the longest
                let now = Instant::now();
                let mut k = 0;
                while k < retry_buf.len() {
                    if retry_buf[k].ready <= now {
                        let e = retry_buf.swap_remove(k);
                        let padded = pad_to_rung(e.batch.len(), &pad_ladder);
                        let job = Job::Run {
                            depth: e.depth,
                            batch: e.batch,
                            padded,
                            attempt: e.attempt,
                            seq: e.seq,
                        };
                        send_to_least_loaded(&job_txs, &mut in_flight, job)?;
                    } else {
                        k += 1;
                    }
                }
                if !draining && Instant::now() >= deadline {
                    // horizon: stop serving; the backlog is unserved
                    stats.unserved += queue.try_drain(usize::MAX).len() as u64;
                    break;
                }
                let target = governor.target_batch(queue.len());
                // drain mode has no horizon: everything accepted is served
                let horizon = if draining { None } else { Some(deadline) };
                let Some(batch) = batcher.next_batch(queue, target, horizon) else {
                    break; // closed and drained (retries flush below)
                };
                if batch.is_empty() {
                    continue; // deadline slice expired with nothing opened
                }
                let padded = pad_to_rung(batch.len(), &pad_ladder);
                let depth = queue.len();
                let seq = batch_seq;
                batch_seq += 1;
                let job = Job::Run { depth, batch, padded, attempt: 1, seq };
                send_to_least_loaded(&job_txs, &mut in_flight, job)?;
            }
            // in-flight batches and pending retries are accepted work:
            // serve them to completion before Finish, with the engine's
            // panic-liveness poll
            while in_flight.iter().sum::<usize>() > 0 || !retry_buf.is_empty() {
                let now = Instant::now();
                let mut k = 0;
                while k < retry_buf.len() {
                    if retry_buf[k].ready <= now {
                        let e = retry_buf.swap_remove(k);
                        let padded = pad_to_rung(e.batch.len(), &pad_ladder);
                        let job = Job::Run {
                            depth: e.depth,
                            batch: e.batch,
                            padded,
                            attempt: e.attempt,
                            seq: e.seq,
                        };
                        send_to_least_loaded(&job_txs, &mut in_flight, job)?;
                    } else {
                        k += 1;
                    }
                }
                match res_rx.recv_timeout(Duration::from_millis(10)) {
                    Ok((w, reply)) => {
                        in_flight[w] -= 1;
                        fold_reply(
                            &mut stats,
                            governor.as_mut(),
                            &mut retry_buf,
                            plan.retry,
                            warmup_ns,
                            reply,
                        )?;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        let dead = in_flight
                            .iter()
                            .enumerate()
                            .any(|(w, &n)| n > 0 && handles[w].is_finished());
                        if dead {
                            return Err(anyhow!(
                                "a serve worker exited owing a reply (panicked?)"
                            ));
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(anyhow!("serve worker pool died mid-batch"));
                    }
                }
            }
            Ok(())
        })();

        // make sure workers wind down even on the error path
        for tx in &job_txs {
            let _ = tx.send(Job::Finish);
        }
        let mut ws_total = WorkspaceStats::default();
        for handle in handles {
            match handle.join() {
                Ok(ws) => ws_total.merge(&ws),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        stats.pack_count = ws_total.pack_count;
        stats.alloc_bytes = ws_total.alloc_bytes;
        outcome.map(|()| stats)
    })
}

/// Send a job to the least-loaded worker (first minimum ⇒ deterministic
/// tie-break), mirroring the virtual clock's earliest-free-worker model.
fn send_to_least_loaded(
    job_txs: &[Sender<Job>],
    in_flight: &mut [usize],
    job: Job,
) -> Result<()> {
    let worker = in_flight
        .iter()
        .enumerate()
        .min_by_key(|&(_, &n)| n)
        .map(|(w, _)| w)
        .expect("workers > 0");
    job_txs[worker]
        .send(job)
        .map_err(|_| anyhow!("serve worker pool shut down"))?;
    in_flight[worker] += 1;
    Ok(())
}

/// Fold one worker reply into the run stats: completions feed the
/// governor, failures consume retry budget and requeue with backoff.
/// Only budget exhaustion is an error.
fn fold_reply(
    stats: &mut ServeStats,
    governor: &mut dyn ServeGovernor,
    retry_buf: &mut Vec<RetryEntry>,
    retry: RetryPolicy,
    warmup_ns: u64,
    reply: WorkerReply,
) -> Result<()> {
    match reply {
        WorkerReply::Done(done) => {
            absorb(stats, governor, done, warmup_ns);
            Ok(())
        }
        WorkerReply::Failed { depth, batch, attempt, seq, err } => {
            stats.failed_batches += 1;
            if attempt >= retry.budget {
                return Err(anyhow!(
                    "retry budget exhausted: batch {seq} ({} request(s)) failed attempt \
                     {attempt} of {}: {err}",
                    batch.len(),
                    retry.budget
                ));
            }
            stats.retries += 1;
            let ready = Instant::now() + Duration::from_nanos(retry.backoff_for(attempt));
            retry_buf.push(RetryEntry { ready, depth, batch, attempt: attempt + 1, seq });
            Ok(())
        }
    }
}

/// Fold one completed batch into the run stats and the governor.
fn absorb(
    stats: &mut ServeStats,
    governor: &mut dyn ServeGovernor,
    done: BatchDone,
    warmup_ns: u64,
) {
    for (&l, &arrival) in done.latencies_ns.iter().zip(&done.arrivals_ns) {
        if arrival >= warmup_ns {
            stats.hist.record(l);
        }
    }
    stats.completed += done.unpadded as u64;
    stats.batches += 1;
    stats.padded_samples += done.padded as u64;
    stats.loss_sum += done.loss;
    stats.correct_sum += done.correct;
    stats.last_done_ns = stats.last_done_ns.max(done.done_ns);
    governor.observe(ServeObservation {
        batch: done.unpadded,
        queue_depth: done.depth,
        latencies_ns: &done.latencies_ns,
    });
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    index: usize,
    jobs: Receiver<Job>,
    results: Sender<(usize, WorkerReply)>,
    rt: &ModelRuntime,
    params: &ParamSet,
    data: &TrainData,
    start: Instant,
    kernel_threads: usize,
    fault: Option<FaultPlan>,
) -> WorkspaceStats {
    let mut bufs = GatherBufs::default();
    // one arena per serve worker for the run's lifetime: params are
    // frozen, so weights pack once and every batch reuses the scratch
    let mut ws = Workspace::with_kernel_threads(kernel_threads);
    while let Ok(job) = jobs.recv() {
        match job {
            Job::Finish => break,
            Job::Run { depth, batch, padded, attempt, seq } => {
                // injected faults fire inside catch_unwind so the panic
                // variant exercises the same recovery path a real
                // worker panic would
                let result = catch_unwind(AssertUnwindSafe(|| {
                    if let Some(f) = fault {
                        if f.should_fail(seq, attempt) {
                            if f.panic {
                                panic!("injected serve fault: batch {seq} attempt {attempt}");
                            }
                            anyhow::bail!(
                                "injected serve fault: batch {seq} attempt {attempt}"
                            );
                        }
                    }
                    super::forward_batch(rt, params, data, &batch, padded, &mut bufs, &mut ws)
                }));
                let reply = match result {
                    Ok(Ok(out)) => {
                        let done_ns = start.elapsed().as_nanos() as u64;
                        WorkerReply::Done(BatchDone {
                            depth,
                            unpadded: batch.len(),
                            padded,
                            latencies_ns: batch
                                .iter()
                                .map(|r| done_ns.saturating_sub(r.arrival_ns))
                                .collect(),
                            arrivals_ns: batch.iter().map(|r| r.arrival_ns).collect(),
                            loss: out.loss,
                            correct: out.correct as f64,
                            done_ns,
                        })
                    }
                    Ok(Err(e)) => {
                        WorkerReply::Failed { depth, batch, attempt, seq, err: e.to_string() }
                    }
                    Err(payload) => {
                        let err = payload
                            .downcast_ref::<&str>()
                            .map(|s| (*s).to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "worker panicked".to_string());
                        WorkerReply::Failed { depth, batch, attempt, seq, err }
                    }
                };
                if results.send((index, reply)).is_err() {
                    break;
                }
            }
        }
    }
    ws.stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec, IMG_LEN};
    use crate::serve::governor::{serve_ladder, QueueDepthGovernor};

    fn tiny_pool() -> TrainData {
        let mut spec = SyntheticSpec::cifar10();
        spec.n_classes = 4;
        spec.train_per_class = 8;
        spec.test_per_class = 4;
        TrainData::Images(generate(&spec).train)
    }

    #[test]
    fn serves_every_request_exactly_once() {
        let data = tiny_pool();
        let ladder = serve_ladder(1, 8);
        let rt = ModelRuntime::reference_serving("serve_ref", IMG_LEN, 4, &ladder);
        let params = ParamSet::init(&rt.entry.params, 3);
        let queue: BoundedQueue<Request> = BoundedQueue::bounded(64);
        let mut gov: Box<dyn ServeGovernor> = Box::new(QueueDepthGovernor::new(1, 8));
        let start = Instant::now();

        let n = 40u64;
        let stats = std::thread::scope(|s| {
            let server = s.spawn(|| {
                serve_wall(
                    &rt,
                    &params,
                    &data,
                    &mut gov,
                    &queue,
                    2,
                    1,
                    Duration::from_millis(2),
                    &ladder,
                    start,
                    0,
                    start + Duration::from_secs(60),
                    &LifecyclePlan::default(),
                    None,
                )
            });
            for id in 0..n {
                let req = Request {
                    id,
                    sample: (id as usize) % data.len(),
                    arrival_ns: start.elapsed().as_nanos() as u64,
                };
                queue.push(req).unwrap();
            }
            queue.close();
            server.join().unwrap()
        })
        .unwrap();

        assert_eq!(stats.completed, n);
        assert!(stats.padded_samples >= n, "padding never shrinks a batch");
        assert!(stats.batches >= 1 && stats.batches <= n);
        assert_eq!(stats.hist.count(), n, "warmup 0: every latency recorded");
        assert!(stats.hist.p99() >= stats.hist.p50());
        assert!(stats.loss_sum.is_finite() && stats.loss_sum > 0.0);
        assert!(stats.last_done_ns > 0);
        assert!(stats.mean_batch() >= 1.0);
        assert_eq!(stats.retries, 0, "no fault plan: nothing retries");
        assert_eq!(stats.failed_batches, 0);
        // serve params are frozen: each worker packs the weight once and
        // serves every batch from its arena afterwards
        assert!(stats.pack_count >= 1, "workers must report packed-cache activity");
        assert!(stats.alloc_bytes > 0);
    }

    #[test]
    fn warmup_filters_histogram_but_not_throughput() {
        let data = tiny_pool();
        let ladder = serve_ladder(1, 4);
        let rt = ModelRuntime::reference_serving("serve_ref", IMG_LEN, 4, &ladder);
        let params = ParamSet::init(&rt.entry.params, 3);
        let queue: BoundedQueue<Request> = BoundedQueue::bounded(64);
        let mut gov: Box<dyn ServeGovernor> = Box::new(QueueDepthGovernor::new(1, 4));
        let start = Instant::now();

        let stats = std::thread::scope(|s| {
            let server = s.spawn(|| {
                serve_wall(
                    &rt,
                    &params,
                    &data,
                    &mut gov,
                    &queue,
                    1,
                    1,
                    Duration::from_millis(1),
                    &ladder,
                    start,
                    u64::MAX, // everything counts as warmup
                    start + Duration::from_secs(60),
                    &LifecyclePlan::default(),
                    None,
                )
            });
            for id in 0..10u64 {
                queue
                    .push(Request { id, sample: id as usize, arrival_ns: 0 })
                    .unwrap();
            }
            queue.close();
            server.join().unwrap()
        })
        .unwrap();

        assert_eq!(stats.completed, 10);
        assert_eq!(stats.hist.count(), 0, "warmup excludes all latencies");
    }

    #[test]
    fn injected_faults_retry_within_budget() {
        let data = tiny_pool();
        let ladder = serve_ladder(1, 8);
        let rt = ModelRuntime::reference_serving("serve_ref", IMG_LEN, 4, &ladder);
        let params = ParamSet::init(&rt.entry.params, 3);
        let queue: BoundedQueue<Request> = BoundedQueue::bounded(64);
        let mut gov: Box<dyn ServeGovernor> = Box::new(QueueDepthGovernor::new(1, 8));
        let start = Instant::now();
        // every batch fails its first attempt, then succeeds on retry
        let plan = LifecyclePlan {
            retry: RetryPolicy { budget: 3, backoff_ns: 100_000 },
            fault: Some(FaultPlan { seed: 7, rate: 1.0, fail_attempts: 1, panic: false }),
            ..LifecyclePlan::default()
        };

        let n = 16u64;
        let stats = std::thread::scope(|s| {
            let server = s.spawn(|| {
                serve_wall(
                    &rt,
                    &params,
                    &data,
                    &mut gov,
                    &queue,
                    2,
                    1,
                    Duration::from_millis(1),
                    &ladder,
                    start,
                    0,
                    start + Duration::from_secs(60),
                    &plan,
                    None,
                )
            });
            for id in 0..n {
                let req = Request {
                    id,
                    sample: (id as usize) % data.len(),
                    arrival_ns: start.elapsed().as_nanos() as u64,
                };
                queue.push(req).unwrap();
            }
            queue.close();
            server.join().unwrap()
        })
        .unwrap();

        assert_eq!(stats.completed, n, "every request survives its retry");
        assert_eq!(stats.hist.count(), n, "retried requests still record latencies");
        assert!(stats.retries >= 1 && stats.failed_batches >= 1);
        assert_eq!(
            stats.retries, stats.failed_batches,
            "rate 1.0 / fail_attempts 1: each batch fails exactly its first attempt"
        );
    }

    #[test]
    fn budget_exhaustion_errors_without_deadlock() {
        let data = tiny_pool();
        let ladder = serve_ladder(1, 4);
        let rt = ModelRuntime::reference_serving("serve_ref", IMG_LEN, 4, &ladder);
        let params = ParamSet::init(&rt.entry.params, 3);
        let queue: BoundedQueue<Request> = BoundedQueue::bounded(64);
        let mut gov: Box<dyn ServeGovernor> = Box::new(QueueDepthGovernor::new(1, 4));
        let start = Instant::now();
        // unbounded fail_attempts: the budget must trip, loudly
        let plan = LifecyclePlan {
            retry: RetryPolicy { budget: 2, backoff_ns: 10_000 },
            fault: Some(FaultPlan { seed: 3, rate: 1.0, fail_attempts: u32::MAX, panic: false }),
            ..LifecyclePlan::default()
        };

        let result = std::thread::scope(|s| {
            let server = s.spawn(|| {
                serve_wall(
                    &rt,
                    &params,
                    &data,
                    &mut gov,
                    &queue,
                    1,
                    1,
                    Duration::from_millis(1),
                    &ladder,
                    start,
                    0,
                    start + Duration::from_secs(60),
                    &plan,
                    None,
                )
            });
            for id in 0..4u64 {
                queue
                    .push(Request { id, sample: id as usize, arrival_ns: 0 })
                    .unwrap();
            }
            queue.close();
            server.join().unwrap()
        });

        let err = result.expect_err("budget exhaustion must surface as an error");
        assert!(
            err.to_string().contains("retry budget exhausted"),
            "unexpected error: {err}"
        );
    }
}
