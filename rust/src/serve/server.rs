//! The wall-clock serving pipeline: batcher/dispatcher + a scoped-thread
//! worker pool running forward-only inference.
//!
//! Reuses the [`crate::coordinator::engine`] idioms: persistent workers
//! fed jobs over per-worker channels, worker-indexed results, and panic
//! liveness — if a worker dies mid-batch the dispatcher surfaces an
//! error instead of hanging (a finished worker owing a reply is a panic;
//! a finished worker with nothing in flight just processed its
//! `Finish`). Unlike the training engine there is **no barrier**: the
//! dispatcher streams batches to the least-loaded worker and folds
//! completions back in whenever they arrive, because serving cares about
//! per-request latency, not synchronous updates.
//!
//! The dispatcher owns the [`ServeGovernor`]: it consults
//! `target_batch(queue depth)` before each drain and feeds every
//! completed batch's latencies back via `observe`, closing the control
//! loop that makes the micro-batch size adaptive.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::Batcher;
use super::governor::{pad_to_rung, ServeGovernor, ServeObservation};
use super::queue::BoundedQueue;
use super::{Request, ServeStats};
use crate::coordinator::dataset::{GatherBufs, TrainData};
use crate::optim::param::ParamSet;
use crate::runtime::{ModelRuntime, Workspace, WorkspaceStats};

enum Job {
    Run {
        /// queue depth right after this batch was drained
        depth: usize,
        batch: Vec<Request>,
        padded: usize,
    },
    Finish,
}

struct BatchDone {
    depth: usize,
    unpadded: usize,
    padded: usize,
    latencies_ns: Vec<u64>,
    /// per-request arrival times, aligned with `latencies_ns` (warmup
    /// filtering is per request, not per batch)
    arrivals_ns: Vec<u64>,
    loss: f64,
    correct: f64,
    done_ns: u64,
}

/// Run the serving pipeline against `queue` until it is closed and
/// drained, or the bench `deadline` (the horizon) passes — whichever
/// comes first; at the deadline, still-queued requests are counted as
/// `unserved`, mirroring the virtual clock's horizon cutoff. Blocks the
/// calling thread (run it under `std::thread::scope` beside the load
/// generator). `start` anchors the bench clock that request `arrival_ns`
/// values were stamped against; requests arriving before `warmup_ns` are
/// served but excluded from the latency histogram.
#[allow(clippy::too_many_arguments)]
pub fn serve_wall(
    rt: &ModelRuntime,
    params: &ParamSet,
    data: &TrainData,
    governor: &mut dyn ServeGovernor,
    queue: &BoundedQueue<Request>,
    workers: usize,
    kernel_threads: usize,
    max_wait: Duration,
    ladder: &[usize],
    start: Instant,
    warmup_ns: u64,
    deadline: Instant,
) -> Result<ServeStats> {
    assert!(workers > 0, "server needs at least one worker");
    assert!(kernel_threads > 0, "server needs at least one kernel thread");
    std::thread::scope(|scope| {
        let (res_tx, res_rx) = channel::<(usize, Result<BatchDone>)>();
        let mut job_txs: Vec<Sender<Job>> = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Job>();
            let res_tx = res_tx.clone();
            handles.push(scope.spawn(move || {
                worker_loop(w, rx, res_tx, rt, params, data, start, kernel_threads)
            }));
            job_txs.push(tx);
        }
        drop(res_tx);

        let batcher = Batcher::new(max_wait);
        let mut stats = ServeStats::default();
        let mut in_flight = vec![0usize; workers];

        let outcome = (|| -> Result<()> {
            loop {
                // fold in any completions that have landed (non-blocking)
                while let Ok((w, res)) = res_rx.try_recv() {
                    in_flight[w] -= 1;
                    absorb(&mut stats, &mut *governor, res?, warmup_ns);
                }
                if Instant::now() >= deadline {
                    // horizon: stop serving; the backlog is unserved
                    stats.unserved += queue.try_drain(usize::MAX).len() as u64;
                    break;
                }
                let target = governor.target_batch(queue.len());
                let Some(batch) = batcher.next_batch(queue, target, Some(deadline)) else {
                    break; // closed and drained
                };
                if batch.is_empty() {
                    continue; // deadline slice expired with nothing queued
                }
                let padded = pad_to_rung(batch.len(), ladder);
                let depth = queue.len();
                // least-loaded dispatch (first minimum ⇒ deterministic
                // tie-break), mirroring the virtual clock's
                // earliest-free-worker model
                let worker = in_flight
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &n)| n)
                    .map(|(w, _)| w)
                    .expect("workers > 0");
                job_txs[worker]
                    .send(Job::Run { depth, batch, padded })
                    .map_err(|_| anyhow!("serve worker pool shut down"))?;
                in_flight[worker] += 1;
            }
            for tx in &job_txs {
                let _ = tx.send(Job::Finish);
            }
            // drain the stragglers, with the engine's panic-liveness poll
            while in_flight.iter().sum::<usize>() > 0 {
                match res_rx.recv_timeout(Duration::from_millis(100)) {
                    Ok((w, res)) => {
                        in_flight[w] -= 1;
                        absorb(&mut stats, &mut *governor, res?, warmup_ns);
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        let dead = in_flight
                            .iter()
                            .enumerate()
                            .any(|(w, &n)| n > 0 && handles[w].is_finished());
                        if dead {
                            return Err(anyhow!(
                                "a serve worker exited owing a reply (panicked?)"
                            ));
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(anyhow!("serve worker pool died mid-batch"));
                    }
                }
            }
            Ok(())
        })();

        // make sure workers wind down even on the error path
        for tx in &job_txs {
            let _ = tx.send(Job::Finish);
        }
        let mut ws_total = WorkspaceStats::default();
        for handle in handles {
            match handle.join() {
                Ok(ws) => ws_total.merge(&ws),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        stats.pack_count = ws_total.pack_count;
        stats.alloc_bytes = ws_total.alloc_bytes;
        outcome.map(|()| stats)
    })
}

/// Fold one completed batch into the run stats and the governor.
fn absorb(
    stats: &mut ServeStats,
    governor: &mut dyn ServeGovernor,
    done: BatchDone,
    warmup_ns: u64,
) {
    for (&l, &arrival) in done.latencies_ns.iter().zip(&done.arrivals_ns) {
        if arrival >= warmup_ns {
            stats.hist.record(l);
        }
    }
    stats.completed += done.unpadded as u64;
    stats.batches += 1;
    stats.padded_samples += done.padded as u64;
    stats.loss_sum += done.loss;
    stats.correct_sum += done.correct;
    stats.last_done_ns = stats.last_done_ns.max(done.done_ns);
    governor.observe(ServeObservation {
        batch: done.unpadded,
        queue_depth: done.depth,
        latencies_ns: &done.latencies_ns,
    });
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    index: usize,
    jobs: Receiver<Job>,
    results: Sender<(usize, Result<BatchDone>)>,
    rt: &ModelRuntime,
    params: &ParamSet,
    data: &TrainData,
    start: Instant,
    kernel_threads: usize,
) -> WorkspaceStats {
    let mut bufs = GatherBufs::default();
    // one arena per serve worker for the run's lifetime: params are
    // frozen, so weights pack once and every batch reuses the scratch
    let mut ws = Workspace::with_kernel_threads(kernel_threads);
    while let Ok(job) = jobs.recv() {
        match job {
            Job::Finish => break,
            Job::Run { depth, batch, padded } => {
                let res =
                    super::forward_batch(rt, params, data, &batch, padded, &mut bufs, &mut ws)
                        .map(|out| {
                            let done_ns = start.elapsed().as_nanos() as u64;
                            BatchDone {
                                depth,
                                unpadded: batch.len(),
                                padded,
                                latencies_ns: batch
                                    .iter()
                                    .map(|r| done_ns.saturating_sub(r.arrival_ns))
                                    .collect(),
                                arrivals_ns: batch.iter().map(|r| r.arrival_ns).collect(),
                                loss: out.loss,
                                correct: out.correct as f64,
                                done_ns,
                            }
                        });
                if results.send((index, res)).is_err() {
                    break;
                }
            }
        }
    }
    ws.stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec, IMG_LEN};
    use crate::serve::governor::{serve_ladder, QueueDepthGovernor};

    fn tiny_pool() -> TrainData {
        let mut spec = SyntheticSpec::cifar10();
        spec.n_classes = 4;
        spec.train_per_class = 8;
        spec.test_per_class = 4;
        TrainData::Images(generate(&spec).train)
    }

    #[test]
    fn serves_every_request_exactly_once() {
        let data = tiny_pool();
        let ladder = serve_ladder(1, 8);
        let rt = ModelRuntime::reference_serving("serve_ref", IMG_LEN, 4, &ladder);
        let params = ParamSet::init(&rt.entry.params, 3);
        let queue: BoundedQueue<Request> = BoundedQueue::bounded(64);
        let mut gov = QueueDepthGovernor::new(1, 8);
        let start = Instant::now();

        let n = 40u64;
        let stats = std::thread::scope(|s| {
            let server = s.spawn(|| {
                serve_wall(
                    &rt,
                    &params,
                    &data,
                    &mut gov,
                    &queue,
                    2,
                    1,
                    Duration::from_millis(2),
                    &ladder,
                    start,
                    0,
                    start + Duration::from_secs(60),
                )
            });
            for id in 0..n {
                let req = Request {
                    id,
                    sample: (id as usize) % data.len(),
                    arrival_ns: start.elapsed().as_nanos() as u64,
                };
                queue.push(req).unwrap();
            }
            queue.close();
            server.join().unwrap()
        })
        .unwrap();

        assert_eq!(stats.completed, n);
        assert!(stats.padded_samples >= n, "padding never shrinks a batch");
        assert!(stats.batches >= 1 && stats.batches <= n);
        assert_eq!(stats.hist.count(), n, "warmup 0: every latency recorded");
        assert!(stats.hist.p99() >= stats.hist.p50());
        assert!(stats.loss_sum.is_finite() && stats.loss_sum > 0.0);
        assert!(stats.last_done_ns > 0);
        assert!(stats.mean_batch() >= 1.0);
        // serve params are frozen: each worker packs the weight once and
        // serves every batch from its arena afterwards
        assert!(stats.pack_count >= 1, "workers must report packed-cache activity");
        assert!(stats.alloc_bytes > 0);
    }

    #[test]
    fn warmup_filters_histogram_but_not_throughput() {
        let data = tiny_pool();
        let ladder = serve_ladder(1, 4);
        let rt = ModelRuntime::reference_serving("serve_ref", IMG_LEN, 4, &ladder);
        let params = ParamSet::init(&rt.entry.params, 3);
        let queue: BoundedQueue<Request> = BoundedQueue::bounded(64);
        let mut gov = QueueDepthGovernor::new(1, 4);
        let start = Instant::now();

        let stats = std::thread::scope(|s| {
            let server = s.spawn(|| {
                serve_wall(
                    &rt,
                    &params,
                    &data,
                    &mut gov,
                    &queue,
                    1,
                    1,
                    Duration::from_millis(1),
                    &ladder,
                    start,
                    u64::MAX, // everything counts as warmup
                    start + Duration::from_secs(60),
                )
            });
            for id in 0..10u64 {
                queue
                    .push(Request { id, sample: id as usize, arrival_ns: 0 })
                    .unwrap();
            }
            queue.close();
            server.join().unwrap()
        })
        .unwrap();

        assert_eq!(stats.completed, 10);
        assert_eq!(stats.hist.count(), 0, "warmup excludes all latencies");
    }
}
