//! Bounded MPMC request queue — the admission edge of the serving path.
//!
//! A `Mutex<VecDeque>` guarded by two condvars: `not_empty` wakes
//! consumers when work arrives, `not_full` wakes producers when capacity
//! frees up, so a blocking [`BoundedQueue::push`] is real backpressure
//! (the producer's thread parks until a drain makes room). The load
//! generator instead uses [`BoundedQueue::try_push`] and counts rejects as
//! *shed* load — an open-loop client must never be slowed by the server it
//! is measuring.
//!
//! Ordering contract: global FIFO. Every push is serialized through the
//! mutex, so per-producer program order is preserved, and drains take from
//! the front — `tests/serve_queue.rs` property-checks exactly-once
//! delivery and per-producer FIFO under N producers × M consumers.
//!
//! Shutdown: [`BoundedQueue::close`] wakes every waiter; pushes fail fast
//! (returning the item), while pops keep draining whatever is already
//! queued and only then report [`Pop::Closed`] — a close never drops an
//! accepted request.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded FIFO queue with blocking push/pop and clean shutdown.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

/// Outcome of a blocking drain.
#[derive(Debug, PartialEq, Eq)]
pub enum Pop<T> {
    /// 1..=max items, in FIFO order.
    Items(Vec<T>),
    /// Nothing arrived within the timeout (queue still open).
    TimedOut,
    /// Queue closed and fully drained.
    Closed,
}

/// Why a [`BoundedQueue::try_push`] was rejected (the item comes back).
#[derive(Debug, PartialEq, Eq)]
pub enum Reject<T> {
    Full(T),
    Closed(T),
}

impl<T> BoundedQueue<T> {
    pub fn bounded(capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be > 0");
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Blocking push: parks until capacity frees (backpressure) or the
    /// queue closes (`Err(item)` — the caller keeps the item).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking push with a deadline: parks until capacity frees, the
    /// queue closes (`Reject::Closed`), or `deadline` passes
    /// (`Reject::Full` — the admission timed out). This is what the
    /// wall-clock load generator's `block` admission policy uses: a
    /// saturated queue applies backpressure only up to the bench
    /// deadline instead of wedging the producer forever.
    pub fn push_deadline(&self, item: T, deadline: Instant) -> Result<(), Reject<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(Reject::Closed(item));
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Reject::Full(item));
            }
            let (guard, _res) = self.not_full.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Push that makes room by evicting queued items from the *front*
    /// while the queue is full and `evict` approves the victim. Returns
    /// the evicted items (possibly empty) on success; `Reject::Full`
    /// (nothing evicted) when the front item is not evictable, and
    /// `Reject::Closed` after close. Powers the shed-oldest and
    /// deadline-aware admission policies.
    pub fn push_evicting(
        &self,
        item: T,
        mut evict: impl FnMut(&T) -> bool,
    ) -> Result<Vec<T>, Reject<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Reject::Closed(item));
        }
        let mut evicted = Vec::new();
        while g.items.len() >= self.capacity {
            match g.items.front() {
                Some(front) if evict(front) => {
                    evicted.push(g.items.pop_front().expect("front exists"));
                }
                // front not evictable (capacity >= 1, so nothing was
                // evicted yet on this path): shed the newcomer instead
                _ => return Err(Reject::Full(item)),
            }
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(evicted)
    }

    /// Non-blocking push; a full or closed queue rejects with the item.
    pub fn try_push(&self, item: T) -> Result<(), Reject<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Reject::Closed(item));
        }
        if g.items.len() >= self.capacity {
            return Err(Reject::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Drain up to `max` immediately-available items without blocking.
    pub fn try_drain(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let n = max.min(g.items.len());
        let out: Vec<T> = g.items.drain(..n).collect();
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Block until at least one item is available (then take up to `max`),
    /// the queue closes empty, or `timeout` elapses.
    pub fn pop_up_to(&self, max: usize, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let n = max.max(1).min(g.items.len());
                let out: Vec<T> = g.items.drain(..n).collect();
                drop(g);
                self.not_full.notify_all();
                return Pop::Items(out);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Close the queue: pushes fail from now on, pops drain the remainder.
    /// Wakes every blocked producer and consumer.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_and_len() {
        let q = BoundedQueue::bounded(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.try_drain(3), vec![0, 1, 2]);
        match q.pop_up_to(10, Duration::from_millis(10)) {
            Pop::Items(v) => assert_eq!(v, vec![3, 4]),
            other => panic!("expected items, got {other:?}"),
        }
        assert!(q.is_empty());
    }

    #[test]
    fn pop_times_out_when_empty() {
        let q: BoundedQueue<u32> = BoundedQueue::bounded(4);
        assert_eq!(q.pop_up_to(1, Duration::from_millis(5)), Pop::TimedOut);
    }

    #[test]
    fn try_push_full_and_closed() {
        let q = BoundedQueue::bounded(1);
        q.try_push(1).unwrap();
        assert_eq!(q.try_push(2), Err(Reject::Full(2)));
        q.close();
        assert_eq!(q.try_push(3), Err(Reject::Closed(3)));
        // close never drops accepted items
        assert_eq!(q.try_drain(8), vec![1]);
        assert_eq!(q.pop_up_to(1, Duration::from_millis(1)), Pop::Closed);
    }

    #[test]
    fn blocking_push_applies_backpressure() {
        let q = BoundedQueue::bounded(1);
        q.push(0u32).unwrap();
        std::thread::scope(|s| {
            let t = s.spawn(|| q.push(1).is_ok());
            // the producer is parked on not_full until we drain
            std::thread::sleep(Duration::from_millis(20));
            assert_eq!(q.try_drain(1), vec![0]);
            assert!(t.join().unwrap());
        });
        assert_eq!(q.try_drain(1), vec![1]);
    }

    #[test]
    fn close_wakes_blocked_producer() {
        let q = BoundedQueue::bounded(1);
        q.push(7u32).unwrap();
        std::thread::scope(|s| {
            let producer = s.spawn(|| q.push(8)); // blocks: full
            std::thread::sleep(Duration::from_millis(20));
            q.close();
            assert_eq!(producer.join().unwrap(), Err(8));
        });
        // the accepted item survives the close
        assert_eq!(q.try_drain(8), vec![7]);
    }

    #[test]
    fn push_deadline_times_out_instead_of_wedging() {
        let q = BoundedQueue::bounded(1);
        q.push(0u32).unwrap();
        let t0 = Instant::now();
        let r = q.push_deadline(1, Instant::now() + Duration::from_millis(20));
        assert_eq!(r, Err(Reject::Full(1)), "full past the deadline: admission timed out");
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // with room it admits immediately
        assert_eq!(q.try_drain(1), vec![0]);
        q.push_deadline(2, Instant::now() + Duration::from_millis(20)).unwrap();
        assert_eq!(q.len(), 1);
        q.close();
        assert_eq!(
            q.push_deadline(3, Instant::now() + Duration::from_millis(5)),
            Err(Reject::Closed(3))
        );
    }

    #[test]
    fn push_deadline_wakes_when_capacity_frees() {
        let q = BoundedQueue::bounded(1);
        q.push(0u32).unwrap();
        std::thread::scope(|s| {
            let t = s.spawn(|| q.push_deadline(1, Instant::now() + Duration::from_secs(30)));
            std::thread::sleep(Duration::from_millis(20));
            assert_eq!(q.try_drain(1), vec![0]);
            assert_eq!(t.join().unwrap(), Ok(()));
        });
        assert_eq!(q.try_drain(1), vec![1]);
    }

    #[test]
    fn push_evicting_head_drop_and_predicate() {
        let q = BoundedQueue::bounded(2);
        q.push(10u32).unwrap();
        q.push(11).unwrap();
        // unconditional eviction = shed-oldest
        assert_eq!(q.push_evicting(12, |_| true), Ok(vec![10]));
        assert_eq!(q.len(), 2);
        // predicate refuses the front: newcomer is rejected, queue intact
        assert_eq!(q.push_evicting(13, |_| false), Err(Reject::Full(13)));
        assert_eq!(q.try_drain(4), vec![11, 12]);
        // room available: no eviction needed
        assert_eq!(q.push_evicting(14, |_| true), Ok(vec![]));
        q.close();
        assert_eq!(q.push_evicting(15, |_| true), Err(Reject::Closed(15)));
        assert_eq!(q.try_drain(4), vec![14]);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: BoundedQueue<u32> = BoundedQueue::bounded(1);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| q.pop_up_to(1, Duration::from_secs(30)));
            std::thread::sleep(Duration::from_millis(20));
            q.close();
            assert_eq!(consumer.join().unwrap(), Pop::Closed);
        });
    }
}
