//! Shared experiment plumbing: runtime construction, scaled datasets, the
//! multi-trial arm runner, and result emission (markdown to stdout, CSV
//! series under `results/`).
//!
//! Scaling contract (DESIGN.md §3): the paper's batch ladders are divided
//! by 4 (128→32, 2048→512, …), its 100/90-epoch runs by 5 (20/18 epochs,
//! decay interval 20→4 / 30→6), and datasets are the synthetic stand-ins.
//! Each experiment module documents its own mapping in its header.

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::config::{reference_runtime, DatasetChoice};
use crate::coordinator::{train, TrainData, TrainerConfig};
use crate::data::synthetic::{generate, SyntheticSpec};
use crate::metrics::{PhaseTimers, RunHistory};
use crate::obs::TelemetryConfig;
use crate::runtime::{default_artifacts_dir, Client, Manifest, ModelRuntime};
use crate::schedule::{AdaBatchPolicy, IntervalGovernor};
use crate::util::stats;
use crate::util::table::{write_series_csv, Series};

/// Shared context for one experiment invocation.
pub struct ExpCtx {
    pub client: Client,
    /// `None` on artifact-less machines: `ref_*` models still run there,
    /// manifest-backed models fail loudly via [`ExpCtx::artifact_manifest`].
    pub manifest: Option<Manifest>,
    pub outdir: PathBuf,
    /// epochs per run (scaled default; CLI-overridable)
    pub epochs: usize,
    /// trials per arm (paper uses 5; scaled default 1–3)
    pub trials: usize,
    pub workers: usize,
    /// base RNG seed: every trial's seed (and telemetry suffix) is
    /// derived from it via [`trial_seed`], never from trial order alone
    pub base_seed: u64,
    /// frontier harness: adaptive best-loss tolerance vs fixed-small
    pub frontier_tolerance: f64,
    /// frontier harness: required simulated-wallclock speedup factor
    pub frontier_gate: f64,
    /// telemetry template for every arm's runs (default: disabled). When
    /// outputs are set, each trial suffixes its paths with `.t<seed>` so
    /// trials never overwrite one another.
    pub telemetry: TelemetryConfig,
}

/// The RNG seed for one trial of one arm: a splitmix64-style mix of the
/// base seed and the trial index. Pure function of `(base, trial)` — two
/// invocations agree no matter how many trials run or in what order, and
/// changing the base seed moves *every* trial's stream (the old
/// `1000 + trial` scheme collided across bases and pinned trial 0 to the
/// same stream forever).
pub fn trial_seed(base: u64, trial: usize) -> u64 {
    let mut z = base ^ (trial as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl ExpCtx {
    pub fn new(epochs: usize, trials: usize) -> Result<ExpCtx> {
        let dir = default_artifacts_dir();
        let manifest = if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir)?)
        } else {
            None
        };
        Ok(ExpCtx {
            client: Client::cpu()?,
            manifest,
            outdir: PathBuf::from("results"),
            epochs,
            trials,
            workers: 1,
            base_seed: 1000,
            frontier_tolerance: 0.02,
            frontier_gate: 2.0,
            telemetry: TelemetryConfig::default(),
        })
    }

    /// The artifact manifest, or a clear error on artifact-less machines.
    pub fn artifact_manifest(&self) -> Result<&Manifest> {
        self.manifest.as_ref().ok_or_else(|| {
            anyhow!("artifacts not built (run `make artifacts`); only ref_* models are available")
        })
    }

    /// Resolve a model name: `ref_linear` / `ref_mlp` / `ref_bigram` map
    /// to the always-available reference backend (default widths),
    /// everything else to the AOT artifact manifest.
    pub fn runtime(&self, model: &str) -> Result<ModelRuntime> {
        let dataset = if model == "ref_bigram" {
            DatasetChoice::Corpus { chars: 0, seq_len: 128 }
        } else {
            DatasetChoice::Cifar10
        };
        if let Some(rt) = reference_runtime(model, &dataset, 128)? {
            return Ok(rt);
        }
        Ok(ModelRuntime::new(
            self.client.clone(),
            self.artifact_manifest()?.model(model)?.clone(),
        ))
    }

    /// Scaled synthetic CIFAR-10 (2000 train / 400 test).
    pub fn cifar10(&self) -> (TrainData, TrainData) {
        let d = generate(&SyntheticSpec::cifar10());
        (TrainData::Images(d.train), TrainData::Images(d.test))
    }

    /// Scaled synthetic CIFAR-100 (2400 train / 600 test).
    pub fn cifar100(&self) -> (TrainData, TrainData) {
        let d = generate(&SyntheticSpec::cifar100());
        (TrainData::Images(d.train), TrainData::Images(d.test))
    }

    /// Scaled synthetic ImageNet (1000 classes × per_class).
    pub fn imagenet(&self, per_class: usize) -> (TrainData, TrainData) {
        let d = generate(&SyntheticSpec::imagenet_sim(per_class));
        (TrainData::Images(d.train), TrainData::Images(d.test))
    }

    /// Run one arm for `trials` seeds; returns per-trial histories. Paper
    /// arms are interval policies, so each trial gets a fresh
    /// [`IntervalGovernor`] over the shared generic loop.
    pub fn run_arm(
        &self,
        rt: &ModelRuntime,
        policy: &AdaBatchPolicy,
        data: &(TrainData, TrainData),
        max_microbatch: Option<usize>,
    ) -> Result<Vec<(RunHistory, PhaseTimers)>> {
        let mut out = Vec::with_capacity(self.trials);
        for trial in 0..self.trials {
            let mut cfg = TrainerConfig::new(self.epochs)
                .with_seed(trial_seed(self.base_seed, trial))
                .with_workers(self.workers)
                .with_telemetry(self.trial_telemetry(trial));
            cfg.max_microbatch = max_microbatch;
            let mut governor = IntervalGovernor::new(policy.clone());
            out.push(train(rt, &cfg, &mut governor, &data.0, &data.1)?);
        }
        Ok(out)
    }

    /// The context's telemetry template with per-trial output paths
    /// (`trace.jsonl` → `trace.jsonl.t<seed>`), so multi-trial arms keep
    /// every trial's trace instead of overwriting the file `trials`
    /// times. The suffix is the trial's *derived seed*, not its ordinal:
    /// the same (base seed, trial) pair always lands on the same file,
    /// however many trials around it run.
    fn trial_telemetry(&self, trial: usize) -> TelemetryConfig {
        let seed = trial_seed(self.base_seed, trial);
        let suffix = |p: &std::path::Path| {
            let mut s = p.as_os_str().to_os_string();
            s.push(format!(".t{seed}"));
            PathBuf::from(s)
        };
        TelemetryConfig {
            trace_out: self.telemetry.trace_out.as_deref().map(suffix),
            metrics_out: self.telemetry.metrics_out.as_deref().map(suffix),
            ..self.telemetry.clone()
        }
    }
}

/// mean ± σ of the best test error across trials — the number the paper's
/// figure legends quote.
pub fn best_error_stats(runs: &[(RunHistory, PhaseTimers)]) -> (f64, f64) {
    let errs: Vec<f64> = runs.iter().map(|(h, _)| h.best_test_error()).collect();
    (stats::mean(&errs), stats::std_dev(&errs))
}

/// Turn trial-0's error curve into a named plot series.
pub fn error_series(name: &str, runs: &[(RunHistory, PhaseTimers)]) -> Series {
    let mut s = Series::new(name);
    if let Some((h, _)) = runs.first() {
        for (x, y) in h.error_series() {
            s.push(x, y);
        }
    }
    s
}

/// Write all series of one figure under `results/<figure>.csv`.
pub fn emit_series(outdir: &PathBuf, figure: &str, series: &[Series]) -> Result<()> {
    let path = outdir.join(format!("{figure}.csv"));
    write_series_csv(&path, series)?;
    println!("(series written to {})", path.display());
    Ok(())
}

/// Format `mean ± σ` as the paper's legends do.
pub fn pm(mean: f64, sd: f64) -> String {
    format!("{:.3} ± {:.3}", mean, sd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochRecord;

    fn hist(errs: &[f64]) -> (RunHistory, PhaseTimers) {
        let mut h = RunHistory::new("x");
        for (i, &e) in errs.iter().enumerate() {
            h.push(EpochRecord {
                epoch: i,
                batch: 32,
                lr: 0.1,
                train_loss: 1.0,
                test_loss: 1.0,
                test_error: e,
                iterations: 1,
                active_workers: 1,
                wall_secs: 0.0,
            });
        }
        (h, PhaseTimers::new())
    }

    #[test]
    fn best_error_stats_across_trials() {
        let runs = vec![hist(&[0.5, 0.4]), hist(&[0.6, 0.45])];
        let (m, s) = best_error_stats(&runs);
        assert!((m - 0.425).abs() < 1e-12);
        assert!(s > 0.0);
    }

    #[test]
    fn series_from_first_trial() {
        let runs = vec![hist(&[0.9, 0.8, 0.7])];
        let s = error_series("arm", &runs);
        assert_eq!(s.points.len(), 3);
        assert_eq!(s.last_y(), Some(0.7));
    }

    #[test]
    fn pm_formatting() {
        assert_eq!(pm(0.1234, 0.0021), "0.123 ± 0.002");
    }

    #[test]
    fn trial_seeds_derive_from_base_not_order() {
        // pure function of (base, trial): reordering or adding trials
        // around a given one never moves its stream
        assert_eq!(trial_seed(1000, 3), trial_seed(1000, 3));
        // distinct trials get distinct streams
        let seeds: Vec<u64> = (0..8).map(|t| trial_seed(1000, t)).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "seed collision: {seeds:?}");
        // a different base moves EVERY trial (the old `1000 + trial`
        // scheme pinned trial k of every base to the same stream)
        for t in 0..8 {
            assert_ne!(trial_seed(1000, t), trial_seed(1001, t));
        }
    }
}
