//! Ablation — the batch-size *criterion family* head-to-head, every arm
//! running through the same generic training loop:
//!
//! * AdaBatch's fixed-interval doubling (§3, the paper's rule);
//! * the gradient-variance / SNR criterion (Byrd et al. 2012; De et al.
//!   2016; Balles et al. 2017);
//! * the gradient-diversity criterion (Yin et al. 2018; DiveBatch);
//! * a fixed small-batch reference.
//!
//! The comparison shows (a) all adaptive arms reach large batches, (b)
//! the interval rule needs no statistics plumbing or threshold tuning —
//! the paper's simplicity argument — while (c) the data-driven rules
//! adapt their transition points to the actual optimization trace. Each
//! criterion is a [`BatchGovernor`]; none required a bespoke loop.

use anyhow::Result;

use super::harness::ExpCtx;
use crate::coordinator::{train, TrainerConfig};
use crate::metrics::RunHistory;
use crate::schedule::{
    AdaBatchPolicy, BatchGovernor, BatchSchedule, DiversityGovernor, GradVarianceController,
    IntervalGovernor, LrSchedule, VarianceGovernor,
};
use crate::util::table::Table;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    println!("## ablation: batch-size criteria (interval vs variance vs diversity)\n");
    let data = ctx.cifar10();
    // AlexNet-lite when artifacts exist; otherwise the reference MLP — a
    // non-convex loss is what separates the data-driven criteria from
    // interval doubling, so the ablation stays meaningful without AOT
    // artifacts.
    let (model, rt) = if ctx.manifest.is_some() {
        ("alexnet_lite_c10", ctx.runtime("alexnet_lite_c10")?)
    } else {
        ("ref_mlp", ctx.runtime("ref_mlp")?)
    };
    let interval = (ctx.epochs / 5).max(1);

    let mut table = Table::new(
        &format!("criterion ablation (synthetic CIFAR-10, {model})"),
        &["arm", "best error", "final batch", "batch transitions", "decisions"],
    );

    // flat LR for the data-driven arms: batch growth *is* the decay (§3.1)
    let flat_lr = || LrSchedule::step(0.01, 1.0, ctx.epochs + 1);
    // Data-driven criteria read per-microbatch gradient statistics, which
    // only exist when an update accumulates ≥ 2 microbatches — cap their
    // device microbatch at the largest native size ≤ half the initial
    // batch (None would let batch 32 run as one native-32 pass and the
    // variance estimate would be identically zero).
    let stats_cap = rt.largest_train_microbatch(32 / 2);

    let mut arms: Vec<(&str, Box<dyn BatchGovernor>, Option<usize>)> = vec![
        (
            "AdaBatch interval ×2",
            Box::new(IntervalGovernor::new(AdaBatchPolicy::new(
                "interval-x2",
                BatchSchedule::doubling(32, interval),
                LrSchedule::step(0.01, 0.75, interval),
            ))),
            None,
        ),
        (
            "gradient-variance ×2",
            Box::new(VarianceGovernor::new(
                GradVarianceController::new(32, 1.0, 8, 2, 512),
                flat_lr(),
            )),
            stats_cap,
        ),
        (
            "gradient-diversity",
            Box::new(DiversityGovernor::new(32, flat_lr(), 8, 2, 512)),
            stats_cap,
        ),
        (
            "fixed 32",
            Box::new(IntervalGovernor::new(AdaBatchPolicy::sec41_fixed(32))),
            None,
        ),
    ];

    for (label, governor, max_microbatch) in arms.iter_mut() {
        let mut cfg = TrainerConfig::new(ctx.epochs).with_seed(21).with_workers(ctx.workers);
        cfg.max_microbatch = *max_microbatch;
        let (hist, _) = train(&rt, &cfg, governor.as_mut(), &data.0, &data.1)?;
        table.row(vec![
            label.to_string(),
            format!("{:.3}", hist.best_test_error()),
            hist.epochs.last().map(|e| e.batch).unwrap_or(0).to_string(),
            format!("{:?}", transitions(&hist)),
            governor.decisions().to_string(),
        ]);
    }

    table.print();
    table.write_csv(&ctx.outdir.join("ablation.csv"))?;
    Ok(())
}

/// Epochs at which the realized batch size changed.
fn transitions(hist: &RunHistory) -> Vec<usize> {
    hist.epochs
        .windows(2)
        .filter(|w| w[1].batch != w[0].batch)
        .map(|w| w[1].epoch)
        .collect()
}
