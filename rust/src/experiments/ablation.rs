//! Ablation — AdaBatch's fixed-interval doubling vs the gradient-variance
//! adaptive criterion (Byrd et al. 2012 / De et al. 2016 / Balles et al.
//! 2017), the alternative §2 positions AdaBatch against.
//!
//! The variance controller doubles the batch when the measured
//! signal-to-noise ratio of the gradient falls below a threshold, using
//! statistics the accumulation loop produces for free. The comparison run
//! shows (a) both reach large batches, (b) the interval rule needs no
//! statistics plumbing or threshold tuning — the paper's simplicity
//! argument — while (c) the variance rule adapts its transition points to
//! the actual optimization trace.

use anyhow::Result;

use super::harness::ExpCtx;
use crate::coordinator::{train, train_variance_adaptive, TrainerConfig};
use crate::schedule::{AdaBatchPolicy, BatchSchedule, GradVarianceController, LrSchedule};
use crate::util::table::Table;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    println!("## ablation: interval doubling vs gradient-variance criterion\n");
    let data = ctx.cifar10();
    let rt = ctx.runtime("alexnet_lite_c10")?;
    let interval = (ctx.epochs / 5).max(1);

    let mut table = Table::new(
        "schedule ablation (synthetic CIFAR-10, AlexNet-lite)",
        &["arm", "best error", "final batch", "batch transitions"],
    );

    // arm 1: the paper's interval rule
    let interval_policy = AdaBatchPolicy::new(
        "interval-x2",
        BatchSchedule::doubling(32, interval),
        LrSchedule::step(0.01, 0.75, interval),
    );
    let cfg = TrainerConfig::new(interval_policy.clone(), ctx.epochs).with_seed(21);
    let (hist, _) = train(&rt, &cfg, &data.0, &data.1)?;
    let transitions: Vec<usize> = interval_policy.batch.transition_epochs(ctx.epochs);
    table.row(vec![
        "AdaBatch interval ×2".into(),
        format!("{:.3}", hist.best_test_error()),
        hist.epochs.last().map(|e| e.batch).unwrap_or(0).to_string(),
        format!("{transitions:?}"),
    ]);

    // arm 2: variance-based controller (same base LR, no step decay — the
    // batch growth *is* the decay)
    let flat_policy = AdaBatchPolicy::new(
        "variance",
        BatchSchedule::Fixed(32),
        LrSchedule::step(0.01, 1.0, ctx.epochs + 1),
    );
    let cfg = TrainerConfig::new(flat_policy, ctx.epochs).with_seed(21);
    let mut ctrl = GradVarianceController::new(32, 1.0, 8, 2, 512);
    let hist = train_variance_adaptive(&rt, &cfg, &mut ctrl, &data.0, &data.1)?;
    let trans: Vec<usize> = hist
        .epochs
        .windows(2)
        .filter(|w| w[1].batch != w[0].batch)
        .map(|w| w[1].epoch)
        .collect();
    table.row(vec![
        "gradient-variance ×2".into(),
        format!("{:.3}", hist.best_test_error()),
        hist.epochs.last().map(|e| e.batch).unwrap_or(0).to_string(),
        format!("{trans:?} ({} decisions)", ctrl.decisions()),
    ]);

    // arm 3: fixed small baseline for reference
    let fixed = AdaBatchPolicy::sec41_fixed(32);
    let cfg = TrainerConfig::new(fixed, ctx.epochs).with_seed(21);
    let (hist, _) = train(&rt, &cfg, &data.0, &data.1)?;
    table.row(vec![
        "fixed 32".into(),
        format!("{:.3}", hist.best_test_error()),
        "32".into(),
        "[]".into(),
    ]);

    table.print();
    table.write_csv(&ctx.outdir.join("ablation.csv"))?;
    Ok(())
}
