//! Convergence-vs-wallclock frontier — the paper's central claim, run as
//! an ablation over the whole governor family:
//!
//! * AdaBatch's fixed-interval doubling (§3, the paper's rule);
//! * the gradient-variance / SNR criterion (Byrd et al. 2012; De et al.
//!   2016);
//! * the gradient-diversity criterion (Yin et al. 2018; DiveBatch);
//! * CABS (Balles et al. 2017): batch ∝ lr · variance / loss;
//! * loss-plateau geometric growth (Sievert & Shah 2019);
//!
//! each crossed with the three [`CouplingRule`]s (none / linear / sqrt —
//! AdaBatch §3's LR-rescaling-on-growth), against a fixed-small-batch
//! baseline (Masters & Luschi 2018's counterpoint: small batches
//! converge best, so *that* is the loss target to defend).
//!
//! Every cell trains the same model from the same seed through the same
//! generic loop, then the harness prices its realized per-epoch batch
//! sequence on the simulator's 4×P100 NVLink cluster
//! ([`ClusterModel::sharded_epoch_cost`]). The frontier verdict per
//! adaptive cell:
//!
//! * **converged** — best test loss ≤ baseline best × (1 + tolerance);
//! * **fast** — simulated wallclock ≥ `speedup_gate`× better than the
//!   baseline's;
//! * **pass** — both (and the run did not diverge).
//!
//! `frontier_ok` is true when ≥ 1 adaptive cell passes — "small-batch
//! convergence at large-batch throughput". The JSON report is a pure
//! function of (seed, config): CI runs the harness twice and
//! byte-compares (`frontier-smoke`), exactly like `serve_determinism`.

use anyhow::Result;

use super::harness::ExpCtx;
use crate::coordinator::{train, TrainData, TrainerConfig};
use crate::metrics::RunHistory;
use crate::runtime::ModelRuntime;
use crate::schedule::{
    AdaBatchPolicy, BatchGovernor, BatchSchedule, CabsGovernor, CouplingRule, DiversityGovernor,
    GradVarianceController, IntervalGovernor, LrSchedule, SievertGovernor, VarianceGovernor,
};
use crate::simulator::{ClusterModel, GpuModel, Interconnect, Workload};
use crate::util::json::Json;
use crate::util::table::Table;

/// The governor axis of the frontier grid.
pub const GOVERNORS: &[&str] = &["interval", "variance", "diversity", "cabs", "sievert"];

/// The coupling axis of the frontier grid.
pub const COUPLINGS: &[CouplingRule] =
    &[CouplingRule::None, CouplingRule::Linear, CouplingRule::Sqrt];

/// Static shape of one frontier sweep (the grid axes come from
/// [`GOVERNORS`] × [`COUPLINGS`]; epochs / seed / tolerance / speedup
/// gate ride on [`ExpCtx`]).
#[derive(Debug, Clone)]
pub struct FrontierSpec<'a> {
    /// model-family label recorded in every cell
    pub model: &'a str,
    /// fixed-small baseline batch and every adaptive arm's start
    pub initial_batch: usize,
    /// geometric-ladder cap for every adaptive arm
    pub max_batch: usize,
    /// base LR schedule shared by the baseline and every cell: step decay
    /// `base_lr × lr_decay^(epoch/interval)`. With linear coupling the
    /// adaptive arm's *per-sample* effective step then matches the
    /// baseline's exactly — the paper's §4.1 matched-pair construction.
    pub base_lr: f64,
    pub lr_decay: f64,
    /// decision window (iterations) for the data-driven governors
    pub window: usize,
}

impl FrontierSpec<'_> {
    /// The §4-scaled default: the b=32 ladder on the reference MLP.
    pub fn ref_mlp() -> FrontierSpec<'static> {
        FrontierSpec {
            model: "ref_mlp",
            initial_batch: 32,
            max_batch: 512,
            base_lr: 0.01,
            lr_decay: 0.75,
            window: 8,
        }
    }
}

/// One trained cell, priced on the simulated cluster.
struct CellRun {
    name: String,
    governor: String,
    coupling: CouplingRule,
    hist: RunHistory,
    decisions: usize,
    /// cumulative simulated wallclock at each epoch close
    wall_curve: Vec<f64>,
    /// cumulative update count at each epoch close
    iter_curve: Vec<f64>,
}

impl CellRun {
    fn sim_wall(&self) -> f64 {
        self.wall_curve.last().copied().unwrap_or(0.0)
    }

    /// Best (minimum) finite test loss over the run; +∞ when the run
    /// never produced one (diverged before the first eval).
    fn best_test_loss(&self) -> f64 {
        self.hist
            .epochs
            .iter()
            .map(|e| e.test_loss)
            .filter(|l| l.is_finite())
            .fold(f64::INFINITY, f64::min)
    }

    fn final_batch(&self) -> usize {
        self.hist.epochs.last().map(|e| e.batch).unwrap_or(0)
    }
}

/// The simulated hardware the frontier prices wallclock on: the paper's
/// §4 fleet, 4×P100 over NVLink, gradients exchanged by the chunked ring.
fn frontier_cluster() -> ClusterModel {
    ClusterModel::new(GpuModel::p100(), Interconnect::nvlink_p100(), FRONTIER_GPUS)
}

const FRONTIER_GPUS: usize = 4;
const FRONTIER_CHUNKS: usize = 4;

/// Run the full frontier grid and build the deterministic JSON report.
/// Pure function of (ctx seed/epochs/tolerance/gate, rt, data, spec):
/// no wall-clock value ever enters the report, so two runs at the same
/// seed produce byte-identical output.
pub fn run_frontier(
    ctx: &ExpCtx,
    rt: &ModelRuntime,
    data: &(TrainData, TrainData),
    spec: &FrontierSpec,
) -> Result<Json> {
    let interval = (ctx.epochs / 5).max(1);
    let base_lr = || LrSchedule::step(spec.base_lr, spec.lr_decay, interval);
    let cluster = frontier_cluster();
    let workload = Workload {
        flops_per_sample: rt.entry.flops_per_sample as f64,
        n_samples: data.0.len(),
        param_bytes: rt.entry.total_params() * 4,
    };
    // Data-driven criteria read per-microbatch gradient statistics, which
    // only exist when an update accumulates ≥ 2 microbatches — cap their
    // device microbatch at the largest native size ≤ half the initial
    // batch (None would let the initial batch run as one native pass and
    // the variance estimate would be identically zero).
    let stats_cap = rt.largest_train_microbatch(spec.initial_batch / 2);

    let run_cell = |governor: &mut dyn BatchGovernor, cap: Option<usize>| -> Result<RunHistory> {
        let mut cfg = TrainerConfig::new(ctx.epochs)
            .with_seed(ctx.base_seed)
            .with_workers(ctx.workers);
        cfg.max_microbatch = cap;
        let (hist, _) = train(rt, &cfg, governor, &data.0, &data.1)?;
        Ok(hist)
    };
    let price = |hist: &RunHistory| -> (Vec<f64>, Vec<f64>) {
        let mut wall = Vec::with_capacity(hist.epochs.len());
        let mut iters = Vec::with_capacity(hist.epochs.len());
        let (mut w_acc, mut i_acc) = (0.0f64, 0.0f64);
        for e in &hist.epochs {
            w_acc += cluster.sharded_epoch_cost(&workload, e.batch, FRONTIER_CHUNKS).total();
            i_acc += e.iterations as f64;
            wall.push(w_acc);
            iters.push(i_acc);
        }
        (wall, iters)
    };

    // fixed-small baseline: Masters & Luschi's small-batch convergence
    // sets the loss target every adaptive arm must reach
    let mut fixed = IntervalGovernor::new(AdaBatchPolicy::new(
        "fixed-small",
        BatchSchedule::Fixed(spec.initial_batch),
        base_lr(),
    ));
    let fixed_hist = run_cell(&mut fixed, None)?;
    let (fixed_wall, fixed_iters) = price(&fixed_hist);
    let baseline = CellRun {
        name: "fixed-small".to_string(),
        governor: "fixed".to_string(),
        coupling: CouplingRule::None,
        hist: fixed_hist,
        decisions: 0,
        wall_curve: fixed_wall,
        iter_curve: fixed_iters,
    };

    let mut cells = Vec::new();
    for &gov in GOVERNORS {
        for &rule in COUPLINGS {
            let name = format!("{gov}-{}", rule.name());
            let (mut governor, cap): (Box<dyn BatchGovernor>, Option<usize>) = match gov {
                "interval" => (
                    Box::new(
                        IntervalGovernor::new(AdaBatchPolicy::new(
                            &name,
                            BatchSchedule::AdaBatch {
                                initial: spec.initial_batch,
                                interval_epochs: interval,
                                factor: 2,
                                max_batch: Some(spec.max_batch),
                            },
                            base_lr(),
                        ))
                        .with_coupling(rule),
                    ),
                    None,
                ),
                "variance" => (
                    Box::new(
                        VarianceGovernor::new(
                            GradVarianceController::new(
                                spec.initial_batch,
                                1.0,
                                spec.window,
                                2,
                                spec.max_batch,
                            ),
                            base_lr(),
                        )
                        .with_name(&name)
                        .with_coupling(rule),
                    ),
                    stats_cap,
                ),
                "diversity" => (
                    Box::new(
                        DiversityGovernor::new(
                            spec.initial_batch,
                            base_lr(),
                            spec.window,
                            2,
                            spec.max_batch,
                        )
                        .with_name(&name)
                        .with_coupling(rule),
                    ),
                    stats_cap,
                ),
                "cabs" => (
                    Box::new(
                        CabsGovernor::new(
                            spec.initial_batch,
                            base_lr(),
                            spec.window,
                            2,
                            spec.max_batch,
                        )
                        .with_name(&name)
                        .with_coupling(rule),
                    ),
                    stats_cap,
                ),
                "sievert" => (
                    Box::new(
                        SievertGovernor::new(
                            spec.initial_batch,
                            base_lr(),
                            spec.window,
                            2,
                            spec.max_batch,
                        )
                        .with_name(&name)
                        .with_coupling(rule),
                    ),
                    stats_cap,
                ),
                other => unreachable!("governor {other} not in GOVERNORS"),
            };
            let hist = run_cell(governor.as_mut(), cap)?;
            let (wall_curve, iter_curve) = price(&hist);
            cells.push(CellRun {
                name,
                governor: gov.to_string(),
                coupling: rule,
                hist,
                decisions: governor.decisions(),
                wall_curve,
                iter_curve,
            });
        }
    }

    Ok(report_json(ctx, spec, interval, &baseline, &cells))
}

/// JSON array of losses with non-finite entries mapped to null (NaN is
/// not JSON; skipped-eval epochs carry the previous value, diverged
/// tails can carry NaN).
fn loss_arr(xs: impl Iterator<Item = f64>) -> Json {
    Json::Arr(xs.map(|x| if x.is_finite() { Json::num(x) } else { Json::Null }).collect())
}

fn curve_json(cell: &CellRun) -> Json {
    Json::obj(vec![
        ("iterations", Json::arr_f64(&cell.iter_curve)),
        ("sim_wall_secs", Json::arr_f64(&cell.wall_curve)),
        ("train_loss", loss_arr(cell.hist.epochs.iter().map(|e| e.train_loss))),
        ("test_loss", loss_arr(cell.hist.epochs.iter().map(|e| e.test_loss))),
        ("batch", Json::arr_usize(&cell.hist.epochs.iter().map(|e| e.batch).collect::<Vec<_>>())),
    ])
}

fn cell_json(ctx: &ExpCtx, spec: &FrontierSpec, baseline: &CellRun, cell: &CellRun) -> Json {
    let best = cell.best_test_loss();
    let target = baseline.best_test_loss() * (1.0 + ctx.frontier_tolerance);
    let speedup = baseline.sim_wall() / cell.sim_wall().max(f64::MIN_POSITIVE);
    let converged = best.is_finite() && target.is_finite() && best <= target;
    let fast = speedup >= ctx.frontier_gate;
    let pass = converged && fast && !cell.hist.diverged;
    Json::obj(vec![
        ("name", Json::str(cell.name.clone())),
        ("governor", Json::str(cell.governor.clone())),
        ("coupling", Json::str(cell.coupling.name())),
        ("model", Json::str(spec.model)),
        ("final_batch", Json::num(cell.final_batch() as f64)),
        ("decisions", Json::num(cell.decisions as f64)),
        ("diverged", Json::Bool(cell.hist.diverged)),
        ("best_test_loss", if best.is_finite() { Json::num(best) } else { Json::Null }),
        ("sim_wall_secs", Json::num(cell.sim_wall())),
        ("speedup", Json::num(speedup)),
        ("converged", Json::Bool(converged)),
        ("fast", Json::Bool(fast)),
        ("pass", Json::Bool(pass)),
        ("curve", curve_json(cell)),
    ])
}

fn report_json(
    ctx: &ExpCtx,
    spec: &FrontierSpec,
    interval: usize,
    baseline: &CellRun,
    cells: &[CellRun],
) -> Json {
    let cell_objs: Vec<Json> = cells.iter().map(|c| cell_json(ctx, spec, baseline, c)).collect();
    let frontier_ok = cell_objs
        .iter()
        .any(|c| matches!(c.get("pass"), Some(Json::Bool(true))));
    let base_best = baseline.best_test_loss();
    Json::obj(vec![
        ("report", Json::str("frontier")),
        ("model", Json::str(spec.model)),
        ("epochs", Json::num(ctx.epochs as f64)),
        ("seed", Json::num(ctx.base_seed as f64)),
        ("interval", Json::num(interval as f64)),
        ("initial_batch", Json::num(spec.initial_batch as f64)),
        ("max_batch", Json::num(spec.max_batch as f64)),
        ("base_lr", Json::num(spec.base_lr)),
        ("lr_decay", Json::num(spec.lr_decay)),
        ("tolerance", Json::num(ctx.frontier_tolerance)),
        ("speedup_gate", Json::num(ctx.frontier_gate)),
        ("gpus", Json::num(FRONTIER_GPUS as f64)),
        ("chunks", Json::num(FRONTIER_CHUNKS as f64)),
        (
            "baseline",
            Json::obj(vec![
                ("name", Json::str(baseline.name.clone())),
                (
                    "best_test_loss",
                    if base_best.is_finite() { Json::num(base_best) } else { Json::Null },
                ),
                ("sim_wall_secs", Json::num(baseline.sim_wall())),
                ("curve", curve_json(baseline)),
            ]),
        ),
        ("cells", Json::Arr(cell_objs)),
        ("frontier_ok", Json::Bool(frontier_ok)),
    ])
}

/// CLI entrypoint: run the ref_mlp frontier, print the verdict table and
/// write `results/frontier.json`.
pub fn run(ctx: &ExpCtx) -> Result<()> {
    println!("## ablation: convergence-vs-wallclock frontier (governor × coupling)\n");
    let data = ctx.cifar10();
    let rt = ctx.runtime("ref_mlp")?;
    let spec = FrontierSpec::ref_mlp();
    let report = run_frontier(ctx, &rt, &data, &spec)?;

    let mut table = Table::new(
        &format!(
            "frontier (synthetic CIFAR-10, {}, seed {}, tol {:.0}%, gate {:.1}×)",
            spec.model,
            ctx.base_seed,
            ctx.frontier_tolerance * 100.0,
            ctx.frontier_gate
        ),
        &["cell", "best test loss", "final batch", "sim speedup", "converged", "fast", "pass"],
    );
    let fmt_bool = |j: Option<&Json>| match j {
        Some(Json::Bool(true)) => "yes".to_string(),
        _ => "no".to_string(),
    };
    let fmt_num = |j: Option<&Json>| match j.and_then(Json::as_f64) {
        Some(v) => format!("{v:.3}"),
        None => "—".to_string(),
    };
    if let Some(Json::Arr(cells)) = report.get("cells") {
        for c in cells {
            table.row(vec![
                c.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
                fmt_num(c.get("best_test_loss")),
                fmt_num(c.get("final_batch")),
                fmt_num(c.get("speedup")),
                fmt_bool(c.get("converged")),
                fmt_bool(c.get("fast")),
                fmt_bool(c.get("pass")),
            ]);
        }
    }
    table.print();
    table.write_csv(&ctx.outdir.join("ablation.csv"))?;

    std::fs::create_dir_all(&ctx.outdir)?;
    let path = ctx.outdir.join("frontier.json");
    std::fs::write(&path, format!("{report}\n"))?;
    println!("(frontier report written to {})", path.display());
    let ok = matches!(report.get("frontier_ok"), Some(Json::Bool(true)));
    println!(
        "frontier verdict: {}",
        if ok {
            "PASS — ≥1 adaptive cell reaches the fixed-small loss target at ≥gate speedup"
        } else {
            "FAIL — no adaptive cell on the frontier"
        }
    );
    Ok(())
}
