//! Experiment harnesses — one module per paper table/figure (DESIGN.md §5
//! experiment index), dispatched by name from the CLI
//! (`adabatch experiment <id>`).

pub mod ablation;
pub mod fig12;
pub mod fig3;
pub mod fig4;
pub mod fig567;
pub mod flops;
pub mod harness;
pub mod table1;

use anyhow::{bail, Result};
use harness::ExpCtx;

/// All runnable experiment ids.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "table1", "fig3", "fig4", "fig5", "fig6", "fig7", "flops", "ablation",
];

/// Dispatch one experiment by id.
pub fn run(id: &str, ctx: &ExpCtx) -> Result<()> {
    match id {
        "fig1" => fig12::run(ctx, 10),
        "fig2" => fig12::run(ctx, 100),
        "table1" => table1::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig567::run_fig5(ctx),
        "fig6" => fig567::run_fig6(ctx),
        "fig7" => fig567::run_fig7(ctx),
        "flops" => flops::run(ctx),
        "ablation" => ablation::run(ctx),
        other => bail!("unknown experiment {other:?}; available: {ALL:?}"),
    }
}
