//! Figure 4 — CIFAR-100 test-error *curves* for four arms (§4.2):
//! fixed 128, adaptive 128–2048, fixed 1024 + LR warmup, adaptive
//! 1024–16384 + LR warmup. Claim: the adaptive curves track their fixed
//! counterparts within <1%, and warmup composes with AdaBatch.
//!
//! Scaled arms (÷4 batches, ÷5 epochs): fixed 32, adaptive 32–512,
//! fixed 256+LR, adaptive 256–1024+LR on synthetic CIFAR-100.

use anyhow::Result;

use super::harness::{emit_series, error_series, ExpCtx};
use crate::schedule::{AdaBatchPolicy, BatchSchedule, LrSchedule};
use crate::util::table::Table;

pub fn arms(interval: usize, warmup: usize) -> Vec<(String, AdaBatchPolicy)> {
    vec![
        (
            "fixed 32".into(),
            AdaBatchPolicy::new("fixed-32", BatchSchedule::Fixed(32), LrSchedule::step(0.1, 0.25, interval)),
        ),
        (
            "adaptive 32-512".into(),
            AdaBatchPolicy::new(
                "ada-32",
                BatchSchedule::AdaBatch { initial: 32, interval_epochs: interval, factor: 2, max_batch: Some(512) },
                LrSchedule::step(0.1, 0.5, interval),
            ),
        ),
        (
            "fixed 256 (LR)".into(),
            AdaBatchPolicy::new(
                "fixed-256-lr",
                BatchSchedule::Fixed(256),
                LrSchedule::step_with_warmup(0.1, 0.25, interval, warmup, 8.0),
            ),
        ),
        (
            "adaptive 256-1024 (LR)".into(),
            AdaBatchPolicy::new(
                "ada-256-lr",
                BatchSchedule::AdaBatch { initial: 256, interval_epochs: interval, factor: 2, max_batch: Some(1024) },
                LrSchedule::step_with_warmup(0.1, 0.5, interval, warmup, 8.0),
            ),
        ),
    ]
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    println!("## fig4: CIFAR-100 test error curves, 4 arms (paper §4.2)\n");
    let data = ctx.cifar100();
    let interval = (ctx.epochs / 5).max(1);
    let warmup = (ctx.epochs / 20).max(1);
    let mut series = Vec::new();
    let mut summary = Table::new(
        "fig4 curve endpoints",
        &["network", "arm", "final error", "best error", "final batch"],
    );
    for (disp, model) in [("VGG-lite", "vgg_lite_c100"), ("ResNet-lite", "resnet_lite_c100")] {
        let rt = ctx.runtime(model)?;
        for (label, policy) in arms(interval, warmup) {
            let runs = ctx.run_arm(&rt, &policy, &data, None)?;
            let h = &runs[0].0;
            summary.row(vec![
                disp.to_string(),
                label.clone(),
                format!("{:.3}", h.final_test_error()),
                format!("{:.3}", h.best_test_error()),
                h.epochs.last().map(|e| e.batch).unwrap_or(0).to_string(),
            ]);
            series.push(error_series(&format!("{disp}/{label}"), &runs));
        }
    }
    summary.print();
    emit_series(&ctx.outdir, "fig4", &series)?;
    Ok(())
}
