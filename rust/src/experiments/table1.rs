//! Table 1 — CIFAR-100 forward/backward wall time over 100 epochs,
//! adaptive vs fixed batch (§4.1).
//!
//! Two complementary reproductions:
//!
//! 1. **Measured (this testbed)**: actual fwd+bwd phase seconds from the
//!    CPU PJRT runtime for fixed-small vs adaptive schedules — honest CPU
//!    numbers demonstrating the mechanism (fewer, larger steps).
//! 2. **Modeled (paper's testbed)**: the calibrated P100 model
//!    (`simulator::calibrate` fits the utilization knee to each network's
//!    Table-1 speedup, then the model regenerates the full rows) — this is
//!    where the paper's 1.17–1.49× shape is checked.

use anyhow::Result;

use super::harness::ExpCtx;
use crate::coordinator::{train, TrainerConfig};
use crate::schedule::{AdaBatchPolicy, BatchSchedule, IntervalGovernor, LrSchedule};
use crate::simulator::{calibrate, TABLE1_ANCHORS};
use crate::util::table::Table;

/// Paper Table 1 reference rows (seconds over 100 epochs, mean of 5).
const PAPER_ROWS: &[(&str, &str, f64, f64)] = &[
    ("VGG19_BN", "128", 933.79, 1571.35),
    ("VGG19_BN", "128-2048", 707.13, 1322.59),
    ("ResNet-20", "128", 256.59, 661.35),
    ("ResNet-20", "128-2048", 218.97, 578.63),
    ("AlexNet", "256", 66.24, 129.39),
    ("AlexNet", "256-4096", 44.34, 89.69),
];

pub fn run(ctx: &ExpCtx) -> Result<()> {
    println!("## table1: fwd/bwd running time, adaptive vs fixed (paper §4.1)\n");

    // -- part 1: modeled P100 rows from calibrated knees ------------------
    let mut modeled = Table::new(
        "Table 1 (modeled P100; knee calibrated per network, paper numbers alongside)",
        &["network", "batch", "paper fwd(s)", "model fwd(s)", "paper bwd(s)", "model bwd(s)", "fwd speedup (paper / model)"],
    );
    for anchor in TABLE1_ANCHORS {
        let cal = calibrate(anchor).expect("paper anchors must calibrate");
        let (paper_fixed, paper_ada) = match anchor.network {
            "vgg" => (&PAPER_ROWS[0], &PAPER_ROWS[1]),
            "resnet" => (&PAPER_ROWS[2], &PAPER_ROWS[3]),
            _ => (&PAPER_ROWS[4], &PAPER_ROWS[5]),
        };
        // Solve the implied workload so the fixed row matches exactly, then
        // predict the adaptive row: T ∝ (1 + h/r); scale k from fixed row.
        let sched = BatchSchedule::doubling(anchor.r0, 20);
        let inv_mean = crate::simulator::calibrate::mean_inv_batch(&sched, 100);
        let k_fwd = paper_fixed.2 / (1.0 + cal.r_half_fwd / anchor.r0 as f64);
        let k_bwd = paper_fixed.3 / (1.0 + cal.r_half_bwd / anchor.r0 as f64);
        let model_fixed_fwd = paper_fixed.2; // exact by construction
        let model_ada_fwd = k_fwd * (1.0 + cal.r_half_fwd * inv_mean);
        let model_fixed_bwd = paper_fixed.3;
        let model_ada_bwd = k_bwd * (1.0 + cal.r_half_bwd * inv_mean);
        modeled.row(vec![
            anchor.network.to_string(),
            format!("{}", anchor.r0),
            format!("{:.2}", paper_fixed.2),
            format!("{model_fixed_fwd:.2}"),
            format!("{:.2}", paper_fixed.3),
            format!("{model_fixed_bwd:.2}"),
            "1.00 / 1.00".into(),
        ]);
        modeled.row(vec![
            anchor.network.to_string(),
            format!("{}-{}", anchor.r0, anchor.r0 * 16),
            format!("{:.2}", paper_ada.2),
            format!("{model_ada_fwd:.2}"),
            format!("{:.2}", paper_ada.3),
            format!("{model_ada_bwd:.2}"),
            format!(
                "{:.2} / {:.2}",
                paper_fixed.2 / paper_ada.2,
                model_fixed_fwd / model_ada_fwd
            ),
        ]);
    }
    modeled.print();
    modeled.write_csv(&ctx.outdir.join("table1_modeled.csv"))?;

    // -- part 2: measured CPU phase times on the scaled workload ----------
    let mut measured = Table::new(
        &format!(
            "Table 1 (measured, this CPU testbed: CIFAR-100-sim, {} epochs, scaled ladder)",
            ctx.epochs
        ),
        &["network", "batch", "fwd+bwd (s)", "updates", "speedup"],
    );
    let interval = (ctx.epochs / 5).max(1);
    let data = ctx.cifar100();
    for (disp, model, small) in [
        ("VGG-lite", "vgg_lite_c100", 32usize),
        ("ResNet-lite", "resnet_lite_c100", 32),
        ("AlexNet-lite", "alexnet_lite_c100", 64),
    ] {
        let rt = ctx.runtime(model)?;
        let mut fixed_time = f64::NAN;
        for (label, sched, lr_decay) in [
            ("fixed", BatchSchedule::Fixed(small), 0.375),
            (
                "adaptive",
                BatchSchedule::AdaBatch {
                    initial: small,
                    interval_epochs: interval,
                    factor: 2,
                    max_batch: Some(512),
                },
                0.75,
            ),
        ] {
            let policy = AdaBatchPolicy::new(
                label,
                sched.clone(),
                LrSchedule::step(0.01, lr_decay, interval),
            );
            let cfg = TrainerConfig::new(ctx.epochs).with_seed(0);
            let mut governor = IntervalGovernor::new(policy);
            let (hist, timers) = train(&rt, &cfg, &mut governor, &data.0, &data.1)?;
            let t = timers.total("fwd_bwd").as_secs_f64();
            let updates: usize = hist.epochs.iter().map(|e| e.iterations).sum();
            if label == "fixed" {
                fixed_time = t;
            }
            measured.row(vec![
                disp.to_string(),
                sched.label(ctx.epochs),
                format!("{t:.2}"),
                updates.to_string(),
                format!("{:.2}x", fixed_time / t),
            ]);
        }
    }
    measured.print();
    measured.write_csv(&ctx.outdir.join("table1_measured.csv"))?;
    println!(
        "note: CPU XLA lacks the GPU's batch-efficiency curve, so measured CPU \
         speedups are smaller than the paper's; the modeled P100 rows carry the shape check."
    );
    Ok(())
}
