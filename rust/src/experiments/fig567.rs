//! Figures 5–7 — ImageNet/ResNet-50 convergence under AdaBatch (§4.3),
//! on the synthetic ImageNet stand-in with the deeper 1000-class ResNet.
//!
//! * **Fig 5**: fixed 256/4096/8192/16384 vs adaptive 4096→16384 (double +
//!   LR decay 0.2 every 30 ep, fixed decay 0.1). Gradient accumulation
//!   realizes everything above the 512 device cap (here: µbatch cap 8).
//!   Claim: adaptive ≈ fixed-4096; fixed 8192/16384 are worse.
//! * **Fig 6**: with 5-epoch LR warmup, starting at 8192/16384: adaptive
//!   tracks the small fixed arm and beats the big fixed arms.
//! * **Fig 7**: batch-increase factor sweep ×2/×4/×8 (LR decay
//!   0.2/0.4/0.8): all fine from 8192; ×8 from 16384 diverges (growth too
//!   aggressive too early).
//!
//! Scaling: ladder ÷64 (paper 256…524288 → 4…8192 on 2000 samples),
//! epochs ÷5 with interval 30→6, device cap 512→8 (forcing the same
//! accumulation structure: effective/cap ratios preserved at the start).

use anyhow::Result;

use super::harness::{emit_series, error_series, ExpCtx};
use crate::schedule::{AdaBatchPolicy, BatchSchedule, LrSchedule};
use crate::util::table::Table;

const MODEL: &str = "resnet_deep_c1000";
/// device memory cap, scaled from the paper's 512
const CAP: usize = 8;

fn fixed(batch: usize, interval: usize, warmup: usize, base_batch: usize) -> AdaBatchPolicy {
    let scale = batch as f64 / base_batch as f64;
    let lr = if warmup > 0 && batch > base_batch {
        LrSchedule::step_with_warmup(0.1, 0.1, interval, warmup, scale)
    } else {
        LrSchedule::step(0.1, 0.1, interval)
    };
    AdaBatchPolicy::new(&format!("fixed-{batch}"), BatchSchedule::Fixed(batch), lr)
}

fn adaptive(
    start: usize,
    factor: usize,
    interval: usize,
    warmup: usize,
    base_batch: usize,
    cap: usize,
) -> AdaBatchPolicy {
    let scale = start as f64 / base_batch as f64;
    let decay = 0.1 * factor as f64;
    let lr = if warmup > 0 && start > base_batch {
        LrSchedule::step_with_warmup(0.1, decay, interval, warmup, scale)
    } else {
        LrSchedule::step(0.1, decay, interval)
    };
    AdaBatchPolicy::new(
        &format!("ada-{start}-x{factor}"),
        BatchSchedule::AdaBatch { initial: start, interval_epochs: interval, factor, max_batch: Some(cap) },
        lr,
    )
}

fn run_family(
    ctx: &ExpCtx,
    figure: &str,
    arms: Vec<(String, AdaBatchPolicy)>,
) -> Result<()> {
    // 1000-class stand-in, trimmed for the 1-core budget: 1000 train
    // samples, 256 (class-interleaved, so balanced) test samples
    let data = {
        let (train, test) = ctx.imagenet(1);
        let test = match test {
            crate::coordinator::TrainData::Images(mut d) => {
                d.images.truncate(256 * crate::data::synthetic::IMG_LEN);
                d.labels.truncate(256);
                crate::coordinator::TrainData::Images(d)
            }
            other => other,
        };
        (train, test)
    };
    let rt = ctx.runtime(MODEL)?;
    let mut series = Vec::new();
    let mut summary = Table::new(
        &format!("{figure} endpoints ({} epochs, µbatch cap {CAP} → accumulation)", ctx.epochs),
        &["arm", "final error", "best error", "final batch", "diverged"],
    );
    for (label, policy) in arms {
        let runs = ctx.run_arm(&rt, &policy, &data, Some(CAP))?;
        let h = &runs[0].0;
        summary.row(vec![
            label.clone(),
            format!("{:.3}", h.final_test_error()),
            format!("{:.3}", h.best_test_error()),
            h.epochs.last().map(|e| e.batch).unwrap_or(0).to_string(),
            h.diverged.to_string(),
        ]);
        series.push(error_series(&label, &runs));
    }
    summary.print();
    emit_series(&ctx.outdir, figure, &series)?;
    Ok(())
}

/// Fig 5: no warmup, ladder {4, 64, 128, 256} fixed + adaptive 64→256.
pub fn run_fig5(ctx: &ExpCtx) -> Result<()> {
    println!("## fig5: ImageNet-sim test error, adaptive vs fixed (paper §4.3)\n");
    let interval = (ctx.epochs / 3).max(1);
    let arms = vec![
        ("fixed 8 (≈256)".into(), fixed(8, interval, 0, 8)),
        ("fixed 64 (≈4096)".into(), fixed(64, interval, 0, 8)),
        ("fixed 128 (≈8192)".into(), fixed(128, interval, 0, 8)),
        ("fixed 256 (≈16384)".into(), fixed(256, interval, 0, 8)),
        ("adaptive 64-256".into(), adaptive(64, 2, interval, 0, 8, 256)),
    ];
    run_family(ctx, "fig5", arms)
}

/// Fig 6: warmup arms starting at the scaled 8192 (=128) and 16384 (=256).
pub fn run_fig6(ctx: &ExpCtx) -> Result<()> {
    println!("## fig6: ImageNet-sim with LR warmup, large starts (paper §4.3)\n");
    let interval = (ctx.epochs / 3).max(1);
    let warmup = 1;
    let arms = vec![
        ("fixed 128 (LR)".into(), fixed(128, interval, warmup, 4)),
        ("fixed 256 (LR)".into(), fixed(256, interval, warmup, 4)),
        ("fixed 512 (LR)".into(), fixed(512, interval, warmup, 4)),
        ("adaptive 128-512 (LR)".into(), adaptive(128, 2, interval, warmup, 4, 512)),
        ("adaptive 256-1024 (LR)".into(), adaptive(256, 2, interval, warmup, 4, 1024)),
    ];
    run_family(ctx, "fig6", arms)
}

/// Fig 7: factor sweep ×2/×4/×8 from two starting batches.
pub fn run_fig7(ctx: &ExpCtx) -> Result<()> {
    println!("## fig7: batch-increase factor sweep (paper §4.3)\n");
    let interval = (ctx.epochs / 3).max(1);
    let warmup = 1;
    let arms = vec![
        ("start 128, fixed".into(), fixed(128, interval, warmup, 4)),
        ("start 128, x2".into(), adaptive(128, 2, interval, warmup, 4, 8192)),
        ("start 128, x4".into(), adaptive(128, 4, interval, warmup, 4, 8192)),
        ("start 128, x8".into(), adaptive(128, 8, interval, warmup, 4, 8192)),
        ("start 256, x4".into(), adaptive(256, 4, interval, warmup, 4, 8192)),
        ("start 256, x8".into(), adaptive(256, 8, interval, warmup, 4, 8192)),
    ];
    run_family(ctx, "fig7", arms)
}
