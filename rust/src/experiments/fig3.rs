//! Figure 3 — multi-GPU (4×P100) speedup bars + test errors for large
//! fixed vs adaptive batches with LR warmup (§4.2).
//!
//! Paper arms (VGG19_BN & ResNet-20, CIFAR-100, 100 epochs): baseline
//! fixed 128 (LR 0.1, decay 0.25/20ep); fixed 1024/2048/4096 with 5-epoch
//! warmup; adaptive 1024–16384 / 2048–32768 with warmup, doubling every
//! 20, decay 0.5. Headline: adaptive 1024–16384 reaches 3.54× (VGG) and
//! 6.25× (ResNet) with <2% error change.
//!
//! Reproduction: *test errors* come from functional runs (4 logical
//! workers, ring all-reduce, warmup policies — scaled ladder); *speedups*
//! come from the calibrated 4×P100+NVLink cluster model evaluated on the
//! paper's actual ladder, using each network's real flops/params from the
//! manifest (scaled up by the paper/our width ratio is unnecessary — the
//! ratio cancels in speedups).

use anyhow::Result;

use super::harness::{best_error_stats, emit_series, error_series, pm, ExpCtx};
use crate::schedule::{AdaBatchPolicy, BatchSchedule, LrSchedule};
use crate::simulator::{ClusterModel, GpuModel, Interconnect, Workload};
use crate::util::table::Table;

/// Paper-reported Fig-3 speedups for the adaptive arms (for side-by-side).
const PAPER_HEADLINES: &[(&str, &str, f64)] = &[
    ("vgg", "adaptive 1024-16384 (LR)", 3.54),
    ("resnet", "adaptive 1024-16384 (LR)", 6.25),
];

pub fn run(ctx: &ExpCtx) -> Result<()> {
    println!("## fig3: multi-GPU speedup + test error (paper §4.2)\n");

    // ---- speedup bars from the calibrated cluster model (paper ladder) ----
    // Calibration: one anchor per network — the paper's adaptive-1024
    // headline (3.54× VGG, 6.25× ResNet) pins the utilization knee via
    // bisection; every other bar is then a *prediction*. (The Table-1 knee
    // doesn't transfer: Fig 3's fixed-128 baseline puts only 32 samples on
    // each GPU, a regime Table 1 never measures — see EXPERIMENTS.md.)
    let mut bars = Table::new(
        "fig3 speedups: 4×P100+NVLink model, baseline fixed 128 (anchor = paper headline)",
        &["network", "arm", "modeled speedup", "paper"],
    );
    for (network, headline) in [("vgg", 3.54), ("resnet", 6.25)] {
        // CIFAR-100 workload: 50k samples; flops/params representative of
        // the full-size networks (VGG19 ≈ 0.4 GF/sample, 20M params;
        // ResNet-20 ≈ 41 MF/sample, 0.27M params)
        let w = if network == "vgg" {
            Workload { flops_per_sample: 4.0e8, n_samples: 50_000, param_bytes: 20_000_000 * 4 }
        } else {
            Workload { flops_per_sample: 4.1e7, n_samples: 50_000, param_bytes: 270_000 * 4 }
        };
        let baseline = BatchSchedule::Fixed(128);
        let headline_sched = BatchSchedule::AdaBatch {
            initial: 1024, interval_epochs: 20, factor: 2, max_batch: None,
        };
        let knee = crate::simulator::calibrate::fit_by_bisection(headline, 1.0, 4000.0, |h| {
            let gpu = GpuModel::p100().with_knee(0.55, h);
            ClusterModel::new(gpu, Interconnect::nvlink_p100(), 4)
                .speedup(&w, &baseline, &headline_sched, 100)
        })
        .expect("headline within model range");
        let gpu = GpuModel::p100().with_knee(0.55, knee);
        let cluster = ClusterModel::new(gpu, Interconnect::nvlink_p100(), 4);
        let arms: Vec<(String, BatchSchedule)> = vec![
            ("fixed 1024 (LR)".into(), BatchSchedule::Fixed(1024)),
            ("fixed 2048 (LR)".into(), BatchSchedule::Fixed(2048)),
            ("fixed 4096 (LR)".into(), BatchSchedule::Fixed(4096)),
            (
                "adaptive 1024-16384 (LR)".into(),
                BatchSchedule::AdaBatch { initial: 1024, interval_epochs: 20, factor: 2, max_batch: None },
            ),
            (
                "adaptive 2048-32768 (LR)".into(),
                BatchSchedule::AdaBatch { initial: 2048, interval_epochs: 20, factor: 2, max_batch: None },
            ),
        ];
        for (label, sched) in arms {
            let s = cluster.speedup(&w, &baseline, &sched, 100);
            let paper = PAPER_HEADLINES
                .iter()
                .find(|(n, l, _)| *n == network && *l == label)
                .map(|(_, _, v)| format!("{v:.2}x (anchor)"))
                .unwrap_or_else(|| "—".into());
            bars.row(vec![network.to_string(), label, format!("{s:.2}x"), paper]);
        }
        println!("({network}: calibrated knee r_half = {knee:.0} samples/GPU)");
    }
    bars.print();
    bars.write_csv(&ctx.outdir.join("fig3_speedups.csv"))?;

    // ---- functional test errors with 4 logical workers (scaled ladder) ----
    let data = ctx.cifar100();
    let interval = (ctx.epochs / 5).max(1);
    let warmup = (ctx.epochs / 20).max(1);
    let mut errs = Table::new(
        &format!(
            "fig3 test errors: functional runs, 4 workers, {} epochs (scaled ladder /4)",
            ctx.epochs
        ),
        &["network", "arm", "best error"],
    );
    let mut series = Vec::new();
    for (disp, model) in [("VGG-lite", "vgg_lite_c100"), ("ResNet-lite", "resnet_lite_c100")] {
        let rt = ctx.runtime(model)?;
        let arms = vec![
            (
                "baseline fixed 32".to_string(),
                AdaBatchPolicy::new("b32", BatchSchedule::Fixed(32), LrSchedule::step(0.1, 0.25, interval)),
            ),
            (
                "fixed 256 (LR)".to_string(),
                AdaBatchPolicy::new(
                    "f256",
                    BatchSchedule::Fixed(256),
                    LrSchedule::step_with_warmup(0.1, 0.25, interval, warmup, 256.0 / 32.0),
                ),
            ),
            (
                "adaptive 256-1024 (LR)".to_string(),
                AdaBatchPolicy::new(
                    "a256",
                    BatchSchedule::AdaBatch { initial: 256, interval_epochs: interval, factor: 2, max_batch: Some(1024) },
                    LrSchedule::step_with_warmup(0.1, 0.5, interval, warmup, 256.0 / 32.0),
                ),
            ),
        ];
        for (label, policy) in arms {
            let mut c = ExpCtx {
                client: ctx.client.clone(),
                manifest: ctx.manifest.clone(),
                outdir: ctx.outdir.clone(),
                epochs: ctx.epochs,
                trials: ctx.trials,
                workers: 4,
            };
            c.workers = 4;
            let runs = c.run_arm(&rt, &policy, &data, None)?;
            let (m, s) = best_error_stats(&runs);
            errs.row(vec![disp.to_string(), label.clone(), pm(m, s)]);
            series.push(error_series(&format!("{disp}/{label}"), &runs));
        }
    }
    errs.print();
    emit_series(&ctx.outdir, "fig3_errors", &series)?;
    Ok(())
}
