//! Figures 1 & 2 — CIFAR-10/100 test error: adaptive vs fixed small vs
//! fixed large batch (§4.1).
//!
//! Paper setup: VGG19_BN / ResNet-20 / AlexNet, 100 epochs, base LR 0.01,
//! SGD momentum 0.9 wd 5e-4; fixed arms decay LR ×0.375 every 20 epochs;
//! adaptive arm decays ×0.75 and doubles the batch at the same points
//! (equal effective LR). Fixed batches 256 & 4096 (VGG/ResNet), 512 & 8192
//! (AlexNet). Claim: adaptive stays within 1% of the small fixed batch;
//! the large fixed batch is clearly worse.
//!
//! Scaling (÷4 batches, ÷5 epochs): 20 epochs, interval 4, fixed {32, 512}
//! (AlexNet {64, 512}), adaptive 32→512 (64→1024 capped by data), on the
//! synthetic CIFAR stand-ins. What must reproduce: the *ordering*
//! adaptive ≈ fixed-small < fixed-large, and the <1–2% gap.

use anyhow::Result;

use super::harness::{best_error_stats, emit_series, error_series, pm, ExpCtx};
use crate::schedule::{AdaBatchPolicy, BatchSchedule, LrSchedule};
use crate::util::table::Table;

pub struct Arm {
    pub label: String,
    pub policy: AdaBatchPolicy,
}

/// The §4.1 trio of arms at a scaled ladder.
pub fn sec41_arms(small: usize, large: usize, interval: usize) -> Vec<Arm> {
    vec![
        Arm {
            label: format!("fixed {small}"),
            policy: AdaBatchPolicy::new(
                &format!("fixed-{small}"),
                BatchSchedule::Fixed(small),
                LrSchedule::step(0.01, 0.375, interval),
            ),
        },
        Arm {
            label: format!("fixed {large}"),
            policy: AdaBatchPolicy::new(
                &format!("fixed-{large}"),
                BatchSchedule::Fixed(large),
                LrSchedule::step(0.01, 0.375, interval),
            ),
        },
        Arm {
            label: format!("adaptive {small}-"),
            policy: AdaBatchPolicy::new(
                "adabatch",
                BatchSchedule::AdaBatch {
                    initial: small,
                    interval_epochs: interval,
                    factor: 2,
                    max_batch: Some(large),
                },
                LrSchedule::step(0.01, 0.75, interval),
            ),
        },
    ]
}

pub fn networks(classes: usize) -> Vec<(&'static str, String)> {
    vec![
        ("VGG-lite", format!("vgg_lite_c{classes}")),
        ("ResNet-lite", format!("resnet_lite_c{classes}")),
        ("AlexNet-lite", format!("alexnet_lite_c{classes}")),
    ]
}

/// Run fig1 (classes=10) or fig2 (classes=100).
pub fn run(ctx: &ExpCtx, classes: usize) -> Result<()> {
    let figure = if classes == 10 { "fig1" } else { "fig2" };
    println!(
        "## {figure}: CIFAR-{classes} test error, adaptive vs fixed (paper §4.1)\n"
    );
    let data = if classes == 10 { ctx.cifar10() } else { ctx.cifar100() };
    let interval = (ctx.epochs / 5).max(1);
    let mut table = Table::new(
        &format!("{figure}: lowest test error (mean ± σ over {} trial(s))", ctx.trials),
        &["network", "arm", "final batch", "best error", "within-1% of small?"],
    );
    let mut all_series = Vec::new();
    for (disp, model) in networks(classes) {
        let rt = ctx.runtime(&model)?;
        let arms = sec41_arms(32, 512, interval);
        let mut small_err = f64::NAN;
        for (i, arm) in arms.iter().enumerate() {
            let runs = ctx.run_arm(&rt, &arm.policy, &data, None)?;
            let (mean, sd) = best_error_stats(&runs);
            if i == 0 {
                small_err = mean;
            }
            let within = if (mean - small_err) <= 0.02 { "yes" } else { "no" };
            table.row(vec![
                disp.to_string(),
                arm.label.clone(),
                arm.policy.batch.final_batch(ctx.epochs).to_string(),
                pm(mean, sd),
                within.to_string(),
            ]);
            all_series.push(error_series(&format!("{disp}/{}", arm.label), &runs));
        }
    }
    table.print();
    emit_series(&ctx.outdir, figure, &all_series)?;
    Ok(())
}
