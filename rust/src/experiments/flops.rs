//! §3.3 / Appendix A — flops-per-epoch invariance check.
//!
//! The paper's §3.3 argument: every layer's cost is linear in the batch
//! size, so flops/iteration grows with r while flops/epoch is constant.
//! Two validations:
//!
//! 1. **Analytic**: per-sample flops from the manifest × samples/epoch is
//!    independent of r by construction; we tabulate flops/iteration vs
//!    flops/epoch across the ladder.
//! 2. **Measured**: wall time per *sample* through the real runtime as a
//!    function of microbatch — the CPU analogue of the efficiency curve
//!    u(r) (time/sample should be flat-to-falling, never rising linearly,
//!    confirming the linear-flops property end to end).

use anyhow::Result;
use std::time::Instant;

use super::harness::ExpCtx;
use crate::coordinator::{GatherBufs, TrainData};
use crate::optim::param::ParamSet;
use crate::runtime::{Dtype, HostBatch, StepKind, Workspace};
use crate::util::table::Table;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    println!("## flops: work-per-epoch invariance (paper §3.3 / Appendix A)\n");
    let mut analytic = Table::new(
        "analytic flops (fwd, from manifest): iteration grows ∝ r, epoch constant",
        &["model", "r", "flops/iter", "flops/epoch (n=2000)"],
    );
    for model in ["alexnet_lite_c100", "vgg_lite_c100", "resnet_lite_c100"] {
        let entry = ctx.artifact_manifest()?.model(model)?;
        let f = entry.flops_per_sample as f64;
        for r in [32usize, 128, 512, 2048] {
            let iters = 2000 / r.max(1);
            analytic.row(vec![
                model.to_string(),
                r.to_string(),
                format!("{:.3e}", f * r as f64),
                format!("{:.3e}", f * r as f64 * iters.max(1) as f64),
            ]);
        }
    }
    analytic.print();
    analytic.write_csv(&ctx.outdir.join("flops_analytic.csv"))?;

    // measured per-sample step time across native microbatches
    let mut measured = Table::new(
        "measured fwd+bwd per sample vs native microbatch (CPU PJRT)",
        &["model", "µbatch", "ms/step", "ms/sample"],
    );
    let (train_data, _) = ctx.cifar100();
    for model in ["resnet_lite_c100", "alexnet_lite_c100"] {
        let rt = ctx.runtime(model)?;
        let params = ParamSet::init(&rt.entry.params, 0);
        let mut bufs = GatherBufs::default();
        let mut ws = Workspace::new();
        for &mb in rt.entry.train_batches().iter() {
            let exe = rt.executable(StepKind::Train, mb)?;
            let idx: Vec<usize> = (0..mb).collect();
            train_data.gather(&idx, mb, &mut bufs);
            let x = match train_data.x_dtype() {
                Dtype::F32 => HostBatch::F32(&bufs.x_f32),
                Dtype::I32 => HostBatch::I32(&bufs.x_i32),
            };
            // warmup + timed reps
            exe.run(&params, x, &bufs.y, &mut ws)?;
            let reps = 3;
            let t0 = Instant::now();
            for _ in 0..reps {
                exe.run(&params, x, &bufs.y, &mut ws)?;
            }
            let per_step = t0.elapsed().as_secs_f64() / reps as f64;
            measured.row(vec![
                model.to_string(),
                mb.to_string(),
                format!("{:.1}", per_step * 1e3),
                format!("{:.2}", per_step * 1e3 / mb as f64),
            ]);
        }
        let _ = TrainData::Images; // keep import shape stable
    }
    measured.print();
    measured.write_csv(&ctx.outdir.join("flops_measured.csv"))?;
    Ok(())
}
