"""L1 correctness: fused SGD-momentum kernel and batch-norm kernel vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import batchnorm, ref, sgd


@pytest.mark.parametrize("n", [1, 7, 1024, 4097])
@pytest.mark.parametrize("mu,wd", [(0.9, 5e-4), (0.0, 0.0), (0.99, 1e-4)])
def test_sgd_matches_ref(n, mu, wd):
    rng = np.random.default_rng(n)
    p, g, v = (jnp.asarray(rng.standard_normal(n), jnp.float32) for _ in range(3))
    lr = jnp.float32(0.05)
    p2, v2 = sgd.sgd_momentum(p, g, v, lr, mu, wd)
    pr, vr = ref.sgd_momentum_update(p, g, v, lr, mu, wd)
    np.testing.assert_allclose(p2, pr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(v2, vr, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 3000),
    lr=st.floats(1e-5, 1.0),
    mu=st.floats(0.0, 0.999),
    wd=st.floats(0.0, 1e-2),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_hypothesis(n, lr, mu, wd, seed):
    rng = np.random.default_rng(seed)
    p, g, v = (jnp.asarray(rng.standard_normal(n), jnp.float32) for _ in range(3))
    lrj = jnp.float32(lr)
    p2, v2 = sgd.sgd_momentum(p, g, v, lrj, mu, wd)
    pr, vr = ref.sgd_momentum_update(p, g, v, lrj, mu, wd)
    np.testing.assert_allclose(p2, pr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v2, vr, rtol=1e-5, atol=1e-5)


def test_sgd_zero_grad_with_decay_still_moves():
    p = jnp.ones(16)
    g = jnp.zeros(16)
    v = jnp.zeros(16)
    p2, v2 = sgd.sgd_momentum(p, g, v, jnp.float32(0.1), 0.9, 1e-2)
    # v' = wd*p = 0.01, p' = 1 - 0.1*0.01
    np.testing.assert_allclose(p2, np.full(16, 1 - 0.1 * 0.01), rtol=1e-6)


@pytest.mark.parametrize("r,f", [(2, 1), (8, 4), (64, 130), (33, 16), (256, 8)])
def test_bn_matches_ref(r, f):
    rng = np.random.default_rng(r * 31 + f)
    x = jnp.asarray(rng.standard_normal((r, f)) * 2 + 1, jnp.float32)
    ga = jnp.asarray(rng.standard_normal(f), jnp.float32)
    be = jnp.asarray(rng.standard_normal(f), jnp.float32)
    np.testing.assert_allclose(
        batchnorm.batchnorm2d(x, ga, be), ref.batchnorm_forward(x, ga, be),
        rtol=3e-4, atol=3e-4,
    )


@settings(max_examples=20, deadline=None)
@given(r=st.integers(2, 128), f=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_bn_hypothesis(r, f, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((r, f)), jnp.float32)
    ga = jnp.asarray(rng.standard_normal(f), jnp.float32)
    be = jnp.asarray(rng.standard_normal(f), jnp.float32)
    np.testing.assert_allclose(
        batchnorm.batchnorm2d(x, ga, be), ref.batchnorm_forward(x, ga, be),
        rtol=5e-4, atol=5e-4,
    )


def test_bn_output_is_normalized():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((512, 6)) * 5 + 3, jnp.float32)
    out = batchnorm.batchnorm2d(x, jnp.ones(6), jnp.zeros(6))
    np.testing.assert_allclose(np.mean(out, axis=0), np.zeros(6), atol=1e-4)
    np.testing.assert_allclose(np.std(out, axis=0), np.ones(6), atol=1e-2)


def test_bn_vjp_matches_autodiff_of_ref():
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((20, 5)), jnp.float32)
    ga = jnp.asarray(rng.standard_normal(5), jnp.float32)
    be = jnp.asarray(rng.standard_normal(5), jnp.float32)
    f1 = lambda x, ga, be: jnp.sum(jnp.cos(batchnorm.batchnorm2d_vjp(x, ga, be)))
    f2 = lambda x, ga, be: jnp.sum(jnp.cos(ref.batchnorm_forward(x, ga, be)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(x, ga, be)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(x, ga, be)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=3e-3, atol=3e-3)
