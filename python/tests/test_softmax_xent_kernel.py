"""L1 correctness: fused softmax-xent kernel vs oracle (loss, count, grad)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, softmax_xent


def _batch(rng, r, m, scale=3.0):
    z = jnp.asarray(rng.standard_normal((r, m)) * scale, jnp.float32)
    y = jnp.asarray(rng.integers(0, m, r), jnp.int32)
    return z, y


@pytest.mark.parametrize("r,m", [(1, 2), (8, 10), (128, 100), (130, 1000), (37, 17)])
def test_loss_and_correct_match_ref(r, m):
    rng = np.random.default_rng(r * 101 + m)
    z, y = _batch(rng, r, m)
    loss, corr = softmax_xent.softmax_xent_loss(z, y)
    lref, cref = ref.softmax_xent(z, y)
    np.testing.assert_allclose(loss, lref, rtol=1e-5, atol=1e-5)
    assert float(corr) == float(cref)


@settings(max_examples=25, deadline=None)
@given(r=st.integers(1, 64), m=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_hypothesis_shapes(r, m, seed):
    rng = np.random.default_rng(seed)
    z, y = _batch(rng, r, m)
    loss, corr = softmax_xent.softmax_xent_loss(z, y)
    lref, cref = ref.softmax_xent(z, y)
    np.testing.assert_allclose(loss, lref, rtol=2e-5, atol=2e-5)
    assert float(corr) == float(cref)


def test_grad_is_p_minus_onehot_over_r():
    """Paper Eq. 17: d mean-loss/d logits == (p - z*)/r."""
    rng = np.random.default_rng(0)
    z, y = _batch(rng, 24, 13)
    g = jax.grad(lambda z: softmax_xent.softmax_xent_loss(z, y)[0])(z)
    np.testing.assert_allclose(g, ref.softmax_xent_grad(z, y), rtol=1e-5, atol=1e-6)


def test_grad_rows_sum_to_zero():
    rng = np.random.default_rng(2)
    z, y = _batch(rng, 16, 9)
    g = jax.grad(lambda z: softmax_xent.softmax_xent_loss(z, y)[0])(z)
    np.testing.assert_allclose(jnp.sum(g, axis=-1), jnp.zeros(16), atol=1e-6)


def test_numerically_stable_large_logits():
    z = jnp.asarray([[1e4, -1e4, 0.0], [5e3, 5e3, 5e3]], jnp.float32)
    y = jnp.asarray([0, 1], jnp.int32)
    loss, corr = softmax_xent.softmax_xent_loss(z, y)
    assert np.isfinite(float(loss))
    lref, _ = ref.softmax_xent(z, y)
    np.testing.assert_allclose(loss, lref, rtol=1e-5, atol=1e-5)


def test_perfect_prediction_low_loss():
    m = 11
    y = jnp.arange(8, dtype=jnp.int32) % m
    z = jax.nn.one_hot(y, m) * 50.0
    loss, corr = softmax_xent.softmax_xent_loss(z, y)
    assert float(loss) < 1e-3
    assert float(corr) == 8.0


def test_batch_mean_scaling():
    """Concatenating a batch with itself leaves mean loss unchanged and
    doubles the correct count — the 1/r contract of Eq. (9)."""
    rng = np.random.default_rng(4)
    z, y = _batch(rng, 10, 6)
    l1, c1 = softmax_xent.softmax_xent_loss(z, y)
    l2, c2 = softmax_xent.softmax_xent_loss(
        jnp.concatenate([z, z]), jnp.concatenate([y, y])
    )
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    assert float(c2) == 2 * float(c1)
