"""L1 correctness: Pallas matmul_bias_act vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-aligned and degenerate ones)
and activations; explicit cases pin the MXU-aligned paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

ACTS = ["none", "relu", "gelu"]


def _arrs(rng, m, k, n):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    return x, w, b


@pytest.mark.parametrize("act", ACTS)
@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (128, 128, 128),  # exactly one MXU tile
        (130, 129, 131),  # tile + ragged tail on every axis
        (256, 64, 16),
        (3, 300, 5),  # k spans multiple tiles
    ],
)
def test_matmul_matches_ref(m, k, n, act):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, w, b = _arrs(rng, m, k, n)
    got = matmul.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arrs(rng, m, k, n)
    got = matmul.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_matmul_raw_no_bias():
    rng = np.random.default_rng(7)
    x, w, _ = _arrs(rng, 17, 23, 9)
    np.testing.assert_allclose(
        matmul.matmul_raw(x, w), jnp.dot(x, w), rtol=3e-5, atol=3e-5
    )


@pytest.mark.parametrize("act", ACTS)
def test_matmul_grads_match_ref(act):
    rng = np.random.default_rng(11)
    x, w, b = _arrs(rng, 12, 7, 9)

    def f(x, w, b):
        return jnp.sum(jnp.sin(matmul.matmul_bias_act(x, w, b, act)))

    def fr(x, w, b):
        return jnp.sum(jnp.sin(ref.matmul_bias_act(x, w, b, act)))

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(a, b_, rtol=3e-4, atol=3e-4)


def test_matmul_linear_in_batch():
    """Section 3.3: doubling the batch (rows of x) must not change per-row
    results — work grows by whole tiles only."""
    rng = np.random.default_rng(3)
    x, w, b = _arrs(rng, 16, 10, 6)
    big = jnp.concatenate([x, x], axis=0)
    out = matmul.matmul_bias_act(big, w, b, "relu")
    np.testing.assert_allclose(out[:16], out[16:], rtol=0, atol=0)
    np.testing.assert_allclose(
        out[:16], matmul.matmul_bias_act(x, w, b, "relu"), rtol=1e-6, atol=1e-6
    )


def test_matmul_jit_compiles():
    rng = np.random.default_rng(5)
    x, w, b = _arrs(rng, 32, 32, 32)
    f = jax.jit(lambda x, w, b: matmul.matmul_bias_act(x, w, b, "relu"))
    np.testing.assert_allclose(
        f(x, w, b), ref.matmul_bias_act(x, w, b, "relu"), rtol=3e-5, atol=3e-5
    )
