"""L2 correctness: model definitions, shapes, grads, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import example_args, make_eval_step, make_train_step
from compile.models import MODEL_REGISTRY, get_model

SMALL_MODELS = [
    "alexnet_lite_c10",
    "vgg_lite_c10",
    "resnet_lite_c10",
    "transformer_s",
]


def _batch_for(model, r, seed=0):
    rng = np.random.default_rng(seed)
    if model.inputs.x_dtype == "f32":
        x = jnp.asarray(rng.standard_normal((r, *model.inputs.x_shape)), jnp.float32)
    else:
        x = jnp.asarray(
            rng.integers(0, model.inputs.n_classes, (r, *model.inputs.x_shape)), jnp.int32
        )
    y = jnp.asarray(
        rng.integers(0, model.inputs.n_classes, (r, *model.inputs.y_shape)), jnp.int32
    )
    return x, y


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_train_step_shapes(name):
    model = get_model(name)
    params = model.init_params(0)
    x, y = _batch_for(model, 4)
    out = make_train_step(model)(*params, x, y)
    assert len(out) == 2 + len(params)
    loss, correct = out[0], out[1]
    assert loss.shape == () and np.isfinite(float(loss))
    n_labels = 4 * model.inputs.labels_per_sample
    assert 0.0 <= float(correct) <= n_labels
    for g, p in zip(out[2:], params):
        assert g.shape == p.shape
        assert np.all(np.isfinite(np.asarray(g)))


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_eval_step_matches_train_forward(name):
    model = get_model(name)
    params = model.init_params(1)
    x, y = _batch_for(model, 4, seed=1)
    tr = make_train_step(model)(*params, x, y)
    ev = make_eval_step(model)(*params, x, y)
    np.testing.assert_allclose(tr[0], ev[0], rtol=1e-5)
    assert float(tr[1]) == float(ev[1])


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_initial_loss_near_uniform(name):
    """Fresh init ~ uniform predictive distribution: loss ≈ log(n_classes)."""
    model = get_model(name)
    params = model.init_params(2)
    x, y = _batch_for(model, 8, seed=2)
    loss, _ = make_eval_step(model)(*params, x, y)
    expect = np.log(model.inputs.n_classes)
    assert 0.3 * expect < float(loss) < 3.0 * expect


def test_sgd_reduces_loss_resnet():
    """A few SGD steps on one fixed batch must drive the loss down — the
    full fwd/bwd signal works end to end in the L2 graph."""
    model = get_model("resnet_lite_c10")
    params = model.init_params(3)
    x, y = _batch_for(model, 16, seed=3)
    step = jax.jit(make_train_step(model))
    first = None
    loss = None
    for i in range(8):
        out = step(*params, x, y)
        loss = float(out[0])
        if first is None:
            first = loss
        grads = out[2:]
        params = [p - 0.05 * g for p, g in zip(params, grads)]
    assert loss < first * 0.8, (first, loss)


def test_sgd_reduces_loss_transformer():
    model = get_model("transformer_s")
    params = model.init_params(4)
    x, y = _batch_for(model, 4, seed=4)
    step = jax.jit(make_train_step(model))
    first = None
    loss = None
    for i in range(6):
        out = step(*params, x, y)
        loss = float(out[0])
        if first is None:
            first = loss
        params = [p - 0.1 * g for p, g in zip(params, out[2:])]
    assert loss < first, (first, loss)


def test_grad_accumulation_equals_large_batch():
    """Paper Eq. (5): the mean of two microbatch gradients equals the
    gradient of the concatenated batch (per-batch-mean convention)."""
    model = get_model("alexnet_lite_c10")
    params = model.init_params(5)
    x1, y1 = _batch_for(model, 8, seed=5)
    x2, y2 = _batch_for(model, 8, seed=6)
    step = make_train_step(model)
    g1 = step(*params, x1, y1)[2:]
    g2 = step(*params, x2, y2)[2:]
    gb = step(*params, jnp.concatenate([x1, x2]), jnp.concatenate([y1, y2]))[2:]
    for a, b, c in zip(g1, g2, gb):
        np.testing.assert_allclose((a + b) / 2.0, c, rtol=2e-3, atol=2e-5)


def test_flops_linear_in_batch_metadata():
    for name in SMALL_MODELS:
        model = get_model(name)
        assert model.flops_per_sample > 0


def test_registry_complete():
    for name in [
        "alexnet_lite_c10", "alexnet_lite_c100", "vgg_lite_c10", "vgg_lite_c100",
        "resnet_lite_c10", "resnet_lite_c100", "resnet_deep_c1000",
        "transformer_s", "transformer_m",
    ]:
        assert name in MODEL_REGISTRY


def test_example_args_match_loss_fn():
    model = get_model("resnet_lite_c10")
    args = example_args(model, 4)
    assert len(args) == len(model.params) + 2
    assert args[-2].shape == (4, 32, 32, 3)
    assert args[-1].dtype == jnp.int32


def test_param_names_unique():
    for name in SMALL_MODELS:
        model = get_model(name)
        names = [p.name for p in model.params]
        assert len(names) == len(set(names))
