"""AOT pipeline: HLO text artifacts are well-formed, parseable, and the
manifest round-trips the contract rust depends on."""

import json
import os

import pytest

from compile import aot
from compile.model import make_eval_step, make_train_step
from compile.models import get_model


@pytest.fixture(scope="module")
def smoke_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(d), "smoke")
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return str(d), manifest


def test_hlo_text_is_parseable_hlo(smoke_dir):
    d, manifest = smoke_dir
    for name, entry in manifest["models"].items():
        for kind in ("train", "eval"):
            for bs, rel in entry["artifacts"][kind].items():
                with open(os.path.join(d, rel)) as f:
                    text = f.read()
                assert text.startswith("HloModule"), rel
                assert "ENTRY" in text, rel


def test_manifest_has_rust_contract(smoke_dir):
    _, manifest = smoke_dir
    for name, entry in manifest["models"].items():
        assert entry["flops_per_sample"] > 0
        inp = entry["input"]
        assert inp["x_dtype"] in ("f32", "i32")
        assert inp["n_classes"] >= 2
        assert inp["labels_per_sample"] >= 1
        model = get_model(name)
        assert len(entry["params"]) == len(model.params)
        for spec, p in zip(entry["params"], model.params):
            assert spec["name"] == p.name
            assert tuple(spec["shape"]) == p.shape
            assert spec["init"][0] in ("zeros", "ones", "normal", "uniform")


def test_train_artifact_has_grad_outputs(smoke_dir):
    """The train artifact's ROOT tuple must have 2 + n_params elements."""
    d, manifest = smoke_dir
    for name, entry in manifest["models"].items():
        n = len(entry["params"])
        rel = next(iter(entry["artifacts"]["train"].values()))
        with open(os.path.join(d, rel)) as f:
            text = f.read()
        # The entry computation returns a tuple; count its element types on
        # the ROOT line.
        root = [l for l in text.splitlines() if "ROOT" in l and "tuple(" in l]
        assert root, f"no ROOT tuple in {rel}"
        arity = root[-1].count("f32[") + root[-1].count("s32[")
        # ROOT line lists the tuple shape then operands; require >= outputs
        assert arity >= 2 + n, (rel, arity, n)


def test_batch_size_specialization(smoke_dir):
    """Artifacts are shape-specialized: the batch size appears in the
    entry parameter shapes."""
    d, manifest = smoke_dir
    entry = manifest["models"]["resnet_lite_c10"]
    rel = entry["artifacts"]["train"]["8"]
    with open(os.path.join(d, rel)) as f:
        text = f.read()
    assert "f32[8,32,32,3]" in text


def test_lower_one_deterministic():
    model = get_model("transformer_s")
    a = aot.lower_one(model, make_eval_step(model), 4)
    b = aot.lower_one(model, make_eval_step(model), 4)
    assert a == b


def test_matrices_reference_known_models():
    from compile.models import MODEL_REGISTRY

    for mname, matrix in aot.MATRICES.items():
        for model_name in matrix:
            assert model_name in MODEL_REGISTRY, (mname, model_name)
