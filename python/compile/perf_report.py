"""L1 static performance analysis: VMEM footprint + MXU utilization
estimates for the Pallas kernels' BlockSpecs (DESIGN.md §Perf).

interpret=True gives CPU-numpy semantics only, so TPU efficiency is
*estimated from kernel structure*: per-grid-step VMEM residency (all
blocks + scratch must fit the ~16 MiB/core budget with double-buffering
headroom) and MXU utilization (fraction of each 128×128×128 systolic pass
doing useful work given the tile shapes).

Usage: cd python && python -m compile.perf_report
"""

from __future__ import annotations

import math

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM
MXU = 128  # systolic array side


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def matmul_report(m: int, k: int, n: int, bm: int, bk: int, bn: int) -> dict:
    """VMEM + MXU numbers for one matmul tiling."""
    # resident per grid step: x tile, w tile, out tile, f32 scratch acc
    vmem = 4 * (bm * bk + bk * bn + bm * bn + bm * bn)
    # double-buffered inputs on real hw:
    vmem_db = vmem + 4 * (bm * bk + bk * bn)
    # MXU utilization: each (bm, bk, bn) tile runs ceil(b*/128) systolic
    # passes; useful fraction is the filled part of each 128-cube
    passes = ceil_div(bm, MXU) * ceil_div(bk, MXU) * ceil_div(bn, MXU)
    useful = (bm * bk * bn) / (passes * MXU**3)
    # padding waste at the problem level
    grid = ceil_div(m, bm) * ceil_div(k, bk) * ceil_div(n, bn)
    problem_useful = (m * k * n) / (grid * bm * bk * bn)
    return {
        "vmem_bytes": vmem,
        "vmem_double_buffered": vmem_db,
        "fits": vmem_db <= VMEM_BYTES,
        "mxu_tile_util": useful,
        "problem_fill": problem_useful,
        "est_mxu_util": useful * problem_useful,
    }


def softmax_xent_report(rows: int, classes: int, row_tile: int) -> dict:
    cp = max(8, 1 << (classes - 1).bit_length())
    vmem = 4 * (row_tile * cp + row_tile + 2)
    return {
        "vmem_bytes": vmem,
        "fits": vmem <= VMEM_BYTES,
        "padded_class_fill": classes / cp,
        "bandwidth_bound": True,  # one pass over logits; no MXU work
    }


def sgd_report(n: int, tile: int) -> dict:
    # reads p,g,v + writes p,v per tile: 5 streams
    vmem = 4 * 5 * tile
    return {
        "vmem_bytes": vmem,
        "fits": vmem <= VMEM_BYTES,
        "streams": 5,
        "arithmetic_intensity_flops_per_byte": 4 / (5 * 4),
    }


def fmt(x) -> str:
    if isinstance(x, bool):
        return "yes" if x else "NO"
    if isinstance(x, float):
        return f"{x:.3f}"
    if isinstance(x, int) and x > 4096:
        return f"{x / 1024:.1f} KiB"
    return str(x)


def main() -> None:
    print("## L1 static perf analysis (TPU estimates from BlockSpecs)\n")
    print("### matmul_bias_act (TILE 128x128x128, clamped on small shapes)\n")
    cases = [
        ("FC head 512x256x100 (cnn)", 512, 256, 100, 128, 128, 128),
        ("transformer qkv 512x256x768", 512, 256, 768, 128, 128, 128),
        ("transformer mlp 512x256x1024", 512, 256, 1024, 128, 128, 128),
        ("LM head 512x256x96", 512, 256, 96, 128, 128, 128),
        ("small test 32x64x16 (clamped)", 32, 64, 16, 32, 64, 16),
    ]
    hdr = ["case", "vmem(2x buf)", "fits", "tile MXU util", "problem fill", "est MXU util"]
    print(" | ".join(hdr))
    print("|".join(["---"] * len(hdr)))
    for name, m, k, n, bm, bk, bn in cases:
        r = matmul_report(m, k, n, bm, bk, bn)
        print(
            f"{name} | {fmt(r['vmem_double_buffered'])} | {fmt(r['fits'])} | "
            f"{fmt(r['mxu_tile_util'])} | {fmt(r['problem_fill'])} | {fmt(r['est_mxu_util'])}"
        )
    print("\n### softmax_xent (row tile 128, classes padded to pow2)\n")
    for rows, classes in [(128, 10), (128, 100), (512, 1000), (512, 96)]:
        r = softmax_xent_report(rows, classes, 128)
        print(
            f"rows={rows} classes={classes}: vmem={fmt(r['vmem_bytes'])} "
            f"fits={fmt(r['fits'])} class-fill={fmt(r['padded_class_fill'])} (bandwidth-bound)"
        )
    print("\n### sgd_momentum (tile 1024)\n")
    r = sgd_report(1 << 20, 1024)
    print(
        f"1M-param update: vmem/tile={fmt(r['vmem_bytes'])} fits={fmt(r['fits'])} "
        f"AI={r['arithmetic_intensity_flops_per_byte']:.2f} flop/B -> HBM-bandwidth-bound "
        f"(fusion saves 3 passes vs unfused p/v/g walk)"
    )
    print(
        "\nNotes: batch growth adds whole m-axis grid steps (linear work, §3.3);\n"
        "tile shapes stay MXU-aligned at every ladder point, so estimated MXU\n"
        "utilization is batch-size-invariant — the TPU analogue of the paper's\n"
        "'flops/epoch constant, efficiency rises with r' argument."
    )


if __name__ == "__main__":
    main()
