"""AOT lowering: jax -> HLO text artifacts + manifest.json.

Run once by ``make artifacts``; python never runs again after this. The
interchange format is HLO **text**, not a serialized HloModuleProto: the
rust side links xla_extension 0.5.1, which rejects the 64-bit instruction
ids jax >= 0.5 emits in protos (`proto.id() <= INT_MAX`); the HLO text
parser reassigns ids and round-trips cleanly (see /opt/xla-example and
DESIGN.md §2).

One artifact per (model, step-kind, microbatch-size): XLA specializes on
shapes, so adaptive batch sizes at the system level become an *artifact
ladder* at the runtime level — the rust executable cache picks the largest
native microbatch that fits, and realizes bigger effective batches by
gradient accumulation (paper §4.3, Eq. 5).

Usage:
    python -m compile.aot --out-dir ../artifacts [--matrix default|full|smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from jax._src.lib import xla_client as xc

from .model import example_args, make_eval_step, make_train_step
from .models import MODEL_REGISTRY, get_model

# Build matrices: model -> (train microbatches, eval batches).
# Chosen so (a) every experiment arm has a native microbatch, (b) the CPU
# Table-1 efficiency sweep has a ladder, (c) total compile time stays
# tractable on one core.
MATRICES = {
    "smoke": {
        "transformer_s": ([4], [4]),
        "resnet_lite_c10": ([8], [16]),
    },
    "default": {
        "alexnet_lite_c10": ([16, 32, 64], [128]),
        "alexnet_lite_c100": ([16, 32, 64], [128]),
        "vgg_lite_c10": ([16, 32], [64]),
        "vgg_lite_c100": ([16, 32], [64]),
        "resnet_lite_c10": ([8, 16, 32, 64], [128]),
        "resnet_lite_c100": ([8, 16, 32, 64], [128]),
        "resnet_deep_c1000": ([8], [16]),
        "transformer_s": ([4, 8], [8]),
        "transformer_m": ([2, 4], [4]),
    },
    "full": {
        name: ([8, 16, 32, 64], [128]) for name in MODEL_REGISTRY
    },
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(model, step_fn, batch: int) -> str:
    lowered = jax.jit(step_fn).lower(*example_args(model, batch))
    return to_hlo_text(lowered)


def build(out_dir: str, matrix_name: str, only_model: str | None = None) -> dict:
    matrix = MATRICES[matrix_name]
    manifest = {"version": 1, "matrix": matrix_name, "models": {}}
    for name, (train_bs, eval_bs) in sorted(matrix.items()):
        if only_model and name != only_model:
            continue
        model = get_model(name)
        mdir = os.path.join(out_dir, name)
        os.makedirs(mdir, exist_ok=True)
        entry = {
            "input": {
                "x_shape": list(model.inputs.x_shape),
                "x_dtype": model.inputs.x_dtype,
                "y_shape": list(model.inputs.y_shape),
                "n_classes": model.inputs.n_classes,
                "labels_per_sample": model.inputs.labels_per_sample,
            },
            "flops_per_sample": model.flops_per_sample,
            "params": [
                {"name": p.name, "shape": list(p.shape), "init": list(p.init)}
                for p in model.params
            ],
            "artifacts": {"train": {}, "eval": {}},
        }
        for kind, bss, maker in (
            ("train", train_bs, make_train_step),
            ("eval", eval_bs, make_eval_step),
        ):
            for bs in bss:
                t0 = time.time()
                text = lower_one(model, maker(model), bs)
                rel = f"{name}/{kind}_bs{bs}.hlo.txt"
                with open(os.path.join(out_dir, rel), "w") as f:
                    f.write(text)
                entry["artifacts"][kind][str(bs)] = rel
                print(
                    f"[aot] {name} {kind} bs={bs}: {len(text)/1e6:.2f} MB "
                    f"in {time.time()-t0:.1f}s",
                    flush=True,
                )
        manifest["models"][name] = entry
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--matrix", default="default", choices=sorted(MATRICES))
    ap.add_argument("--model", default=None, help="restrict to one model")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = build(args.out_dir, args.matrix, args.model)
    # merge with an existing manifest so incremental --model runs compose
    mpath = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old_models = old.get("models", {})
        old_models.update(manifest["models"])
        manifest["models"] = old_models
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {mpath} ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
