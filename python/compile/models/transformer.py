"""GPT-style causal transformer LM for the end-to-end training example.

The harness requires one end-to-end driver training a transformer with the
full stack composed; AdaBatch's contribution is architecture-agnostic (its
CIFAR/ImageNet CNNs are the paper's choice of the day), so the transformer
is the natural modern E2E workload: every attention/MLP matmul runs through
the Pallas ``matmul_bias_act`` kernel and the LM loss through the fused
``softmax_xent`` kernel, i.e. the L1 hot path carries >95% of the flops.

Decoder-only, pre-LayerNorm, learned positional embeddings, multi-head
causal attention. LayerNorm (per-token, not batch-sized) uses plain jnp —
it is not a batch-size-dependent layer, so nothing AdaBatch-relevant lives
there. Labels are next-token ids; the loss flattens [r, T] -> [r*T] rows so
the same Pallas loss kernel and the same rust-side correct-count contract
serve LM and image models alike (``labels_per_sample = T``).
"""

from __future__ import annotations

import math
from typing import List

import jax
import jax.numpy as jnp

from ..kernels.matmul import matmul_bias_act
from ..kernels.softmax_xent import softmax_xent_loss
from .common import InputSpec, ModelDef, ParamBuilder, register


def _layernorm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _build_transformer(
    vocab: int, d_model: int, n_layers: int, n_heads: int, seq_len: int, name: str
) -> ModelDef:
    assert d_model % n_heads == 0
    d_head = d_model // n_heads
    pb = ParamBuilder()
    tok = pb.add("tok_emb", (vocab, d_model), ("normal", 0.02))
    pos = pb.add("pos_emb", (seq_len, d_model), ("normal", 0.02))
    layers = []
    for i in range(n_layers):
        ln1 = pb.bn(f"l{i}.ln1", d_model)  # gamma/beta pair, same spec shape
        qkv = pb.dense(f"l{i}.qkv", d_model, 3 * d_model)
        proj = pb.dense(f"l{i}.proj", d_model, d_model)
        ln2 = pb.bn(f"l{i}.ln2", d_model)
        up = pb.dense(f"l{i}.up", d_model, 4 * d_model)
        down = pb.dense(f"l{i}.down", 4 * d_model, d_model)
        layers.append((ln1, qkv, proj, ln2, up, down))
    lnf = pb.bn("lnf", d_model)
    head = pb.dense("head", d_model, vocab)
    specs = pb.specs

    scale = 1.0 / math.sqrt(d_head)
    neg = jnp.float32(-1e30)

    def loss_fn(p: List[jax.Array], x: jax.Array, y: jax.Array):
        r, t = x.shape
        h = p[tok][x] + p[pos][None, :t, :]

        causal = jnp.tril(jnp.ones((t, t), jnp.float32))
        for (ln1, qkv, proj, ln2, up, down) in layers:
            z = _layernorm(h, p[ln1[0]], p[ln1[1]])
            flat = z.reshape(r * t, d_model)
            qkv_out = matmul_bias_act(flat, p[qkv[0]], p[qkv[1]], "none")
            qkv_out = qkv_out.reshape(r, t, 3, n_heads, d_head)
            q = jnp.transpose(qkv_out[:, :, 0], (0, 2, 1, 3))  # [r, H, T, dh]
            k = jnp.transpose(qkv_out[:, :, 1], (0, 2, 1, 3))
            v = jnp.transpose(qkv_out[:, :, 2], (0, 2, 1, 3))
            att = jnp.einsum("rhtd,rhsd->rhts", q, k) * scale
            att = jnp.where(causal[None, None, :, :] > 0, att, neg)
            att = jax.nn.softmax(att, axis=-1)
            ctx = jnp.einsum("rhts,rhsd->rhtd", att, v)
            ctx = jnp.transpose(ctx, (0, 2, 1, 3)).reshape(r * t, d_model)
            h = h + matmul_bias_act(ctx, p[proj[0]], p[proj[1]], "none").reshape(r, t, d_model)
            z = _layernorm(h, p[ln2[0]], p[ln2[1]])
            flat = z.reshape(r * t, d_model)
            mid = matmul_bias_act(flat, p[up[0]], p[up[1]], "gelu")
            h = h + matmul_bias_act(mid, p[down[0]], p[down[1]], "none").reshape(r, t, d_model)

        h = _layernorm(h, p[lnf[0]], p[lnf[1]])
        logits = matmul_bias_act(h.reshape(r * t, d_model), p[head[0]], p[head[1]], "none")
        return softmax_xent_loss(logits, y.reshape(r * t))

    flops_per_tok = n_layers * (2 * d_model * 3 * d_model + 2 * d_model * d_model
                                + 2 * 2 * seq_len * d_model  # attention scores+ctx (avg)
                                + 2 * d_model * 8 * d_model) + 2 * d_model * vocab
    return ModelDef(
        name=name,
        params=specs,
        inputs=InputSpec((seq_len,), "i32", (seq_len,), vocab, labels_per_sample=seq_len),
        loss_fn=loss_fn,
        flops_per_sample=flops_per_tok * seq_len,
    )


@register("transformer_s")
def _tf_s():
    # ~0.8M params: CI-sized smoke model
    return _build_transformer(vocab=64, d_model=64, n_layers=2, n_heads=4, seq_len=64, name="transformer_s")


@register("transformer_m")
def _tf_m():
    # ~12.8M params: the E2E driver workload (examples/transformer_e2e.rs)
    return _build_transformer(vocab=96, d_model=256, n_layers=6, n_heads=8, seq_len=128, name="transformer_m")
