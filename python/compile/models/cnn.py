"""The paper's CNN family, scaled for a 1-CPU-core testbed.

Section 4 trains AlexNet, VGG19_BN, ResNet-20 (CIFAR) and ResNet-50
(ImageNet). We keep each family's distinguishing topology — AlexNet's
plain conv->FC stack, VGG's BN'd conv blocks with maxpool, ResNet's
identity-skip residual stages with global average pooling — at reduced
width/depth ("-lite"), per DESIGN.md §3 (schedule-equivalence is
architecture-generic; what matters for AdaBatch is the batch-size-dependent
layer, BN, and the depth/residual structure, which are retained).

Convolutions use ``lax.conv_general_dilated`` (NHWC/HWIO); the FC heads and
the loss run through the Pallas kernels (matmul_bias_act, softmax_xent) so
every model exercises the L1 hot path. BN uses the Pallas forward with the
closed-form Eq. 46-49 backward.

All flops counts follow the paper's Section 3.3 / Appendix A accounting
(2*flops for MAC; fwd only — the coordinator multiplies by 3 for fwd+bwd
in the usual 1:2 convention).
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels.batchnorm import batchnorm2d_vjp
from ..kernels.matmul import matmul_bias_act
from ..kernels.softmax_xent import softmax_xent_loss
from .common import InputSpec, ModelDef, ParamBuilder, register

IMG = (32, 32, 3)  # CIFAR-shaped NHWC sample


def _conv(x: jax.Array, w: jax.Array, b: jax.Array, stride: int = 1, padding: str = "SAME") -> jax.Array:
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b[None, None, None, :]


def _bn_nhwc(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    """Spatial batch norm: flatten NHWC -> [n*h*w, c] for the Pallas kernel."""
    n, h, w, c = x.shape
    flat = x.reshape(n * h * w, c)
    return batchnorm2d_vjp(flat, gamma, beta).reshape(n, h, w, c)


def _maxpool2(x: jax.Array) -> jax.Array:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _gap(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


def _head(x2d: jax.Array, w, b, y) -> Tuple[jax.Array, jax.Array]:
    logits = matmul_bias_act(x2d, w, b, "none")
    return softmax_xent_loss(logits, y)


def _conv_flops(h, w, kh, kw, cin, cout, stride=1):
    oh, ow = h // stride, w // stride
    return 2 * oh * ow * kh * kw * cin * cout


# ---------------------------------------------------------------------------
# AlexNet-lite
# ---------------------------------------------------------------------------


def _build_alexnet(n_classes: int, width: int = 32) -> ModelDef:
    pb = ParamBuilder()
    c1 = pb.conv("conv1", 3, 3, 3, width)
    c2 = pb.conv("conv2", 3, 3, width, width * 2)
    c3 = pb.conv("conv3", 3, 3, width * 2, width * 4)
    feat = width * 4 * 4 * 4  # after three stride-2 reductions: 32->16->8->4
    f1 = pb.dense("fc1", feat, 256)
    f2 = pb.dense("fc2", 256, n_classes)
    specs = pb.specs

    def loss_fn(p: List[jax.Array], x: jax.Array, y: jax.Array):
        h = jax.nn.relu(_conv(x, p[c1[0]], p[c1[1]], stride=2))
        h = jax.nn.relu(_conv(h, p[c2[0]], p[c2[1]], stride=2))
        h = jax.nn.relu(_conv(h, p[c3[0]], p[c3[1]], stride=2))
        h = h.reshape(h.shape[0], -1)
        h = matmul_bias_act(h, p[f1[0]], p[f1[1]], "relu")
        return _head(h, p[f2[0]], p[f2[1]], y)

    flops = (
        _conv_flops(32, 32, 3, 3, 3, width, 2)
        + _conv_flops(16, 16, 3, 3, width, width * 2, 2)
        + _conv_flops(8, 8, 3, 3, width * 2, width * 4, 2)
        + 2 * feat * 256
        + 2 * 256 * n_classes
    )
    return ModelDef(
        name=f"alexnet_lite_c{n_classes}",
        params=specs,
        inputs=InputSpec(IMG, "f32", (), n_classes),
        loss_fn=loss_fn,
        flops_per_sample=flops,
    )


# ---------------------------------------------------------------------------
# VGG-lite (BN'd conv pairs + maxpool, VGG19_BN's block structure)
# ---------------------------------------------------------------------------


def _build_vgg(n_classes: int, width: int = 16) -> ModelDef:
    pb = ParamBuilder()
    cfg = [(3, width), (width, width), ("pool",), (width, 2 * width), (2 * width, 2 * width),
           ("pool",), (2 * width, 4 * width), (4 * width, 4 * width), ("pool",)]
    convs = []
    bns = []
    i = 0
    for entry in cfg:
        if entry == ("pool",):
            convs.append(None)
            bns.append(None)
            continue
        cin, cout = entry
        convs.append(pb.conv(f"conv{i}", 3, 3, cin, cout))
        bns.append(pb.bn(f"bn{i}", cout))
        i += 1
    feat = 4 * width * 4 * 4  # 32 -> 16 -> 8 -> 4 via three pools
    f1 = pb.dense("fc1", feat, 128)
    f2 = pb.dense("fc2", 128, n_classes)
    specs = pb.specs

    def loss_fn(p: List[jax.Array], x: jax.Array, y: jax.Array):
        h = x
        for conv_idx, bn_idx in zip(convs, bns):
            if conv_idx is None:
                h = _maxpool2(h)
                continue
            h = _conv(h, p[conv_idx[0]], p[conv_idx[1]])
            h = _bn_nhwc(h, p[bn_idx[0]], p[bn_idx[1]])
            h = jax.nn.relu(h)
        h = h.reshape(h.shape[0], -1)
        h = matmul_bias_act(h, p[f1[0]], p[f1[1]], "relu")
        return _head(h, p[f2[0]], p[f2[1]], y)

    flops = (
        _conv_flops(32, 32, 3, 3, 3, width) + _conv_flops(32, 32, 3, 3, width, width)
        + _conv_flops(16, 16, 3, 3, width, 2 * width) + _conv_flops(16, 16, 3, 3, 2 * width, 2 * width)
        + _conv_flops(8, 8, 3, 3, 2 * width, 4 * width) + _conv_flops(8, 8, 3, 3, 4 * width, 4 * width)
        + 2 * feat * 128 + 2 * 128 * n_classes
    )
    return ModelDef(
        name=f"vgg_lite_c{n_classes}",
        params=specs,
        inputs=InputSpec(IMG, "f32", (), n_classes),
        loss_fn=loss_fn,
        flops_per_sample=flops,
    )


# ---------------------------------------------------------------------------
# ResNet-lite (ResNet-20's 3-stage CIFAR topology, n blocks per stage)
# ---------------------------------------------------------------------------


def _build_resnet(n_classes: int, blocks_per_stage: int = 1, width: int = 16) -> ModelDef:
    pb = ParamBuilder()
    stem = pb.conv("stem", 3, 3, 3, width)
    stem_bn = pb.bn("stem_bn", width)
    stages = []
    cin = width
    for s, cout in enumerate((width, 2 * width, 4 * width)):
        blocks = []
        for b in range(blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            name = f"s{s}b{b}"
            c1 = pb.conv(f"{name}.c1", 3, 3, cin, cout)
            n1 = pb.bn(f"{name}.n1", cout)
            c2 = pb.conv(f"{name}.c2", 3, 3, cout, cout)
            n2 = pb.bn(f"{name}.n2", cout)
            proj = None
            if stride != 1 or cin != cout:
                proj = pb.conv(f"{name}.proj", 1, 1, cin, cout)
            blocks.append((c1, n1, c2, n2, proj, stride))
            cin = cout
        stages.append(blocks)
    head = pb.dense("fc", 4 * width, n_classes)
    specs = pb.specs

    def loss_fn(p: List[jax.Array], x: jax.Array, y: jax.Array):
        h = jax.nn.relu(_bn_nhwc(_conv(x, p[stem[0]], p[stem[1]]), p[stem_bn[0]], p[stem_bn[1]]))
        for blocks in stages:
            for (c1, n1, c2, n2, proj, stride) in blocks:
                shortcut = h
                z = jax.nn.relu(_bn_nhwc(_conv(h, p[c1[0]], p[c1[1]], stride=stride), p[n1[0]], p[n1[1]]))
                z = _bn_nhwc(_conv(z, p[c2[0]], p[c2[1]]), p[n2[0]], p[n2[1]])
                if proj is not None:
                    shortcut = _conv(h, p[proj[0]], p[proj[1]], stride=stride)
                h = jax.nn.relu(z + shortcut)
        h = _gap(h)
        return _head(h, p[head[0]], p[head[1]], y)

    # rough fwd flops: stage s at resolution 32/2^s
    flops = _conv_flops(32, 32, 3, 3, 3, width)
    res = 32
    cin_f = width
    for s, cout in enumerate((width, 2 * width, 4 * width)):
        for b in range(blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            res_out = res // stride
            flops += _conv_flops(res, res, 3, 3, cin_f, cout, stride)
            flops += _conv_flops(res_out, res_out, 3, 3, cout, cout)
            if stride != 1 or cin_f != cout:
                flops += _conv_flops(res, res, 1, 1, cin_f, cout, stride)
            cin_f = cout
            res = res_out
    flops += 2 * 4 * width * n_classes
    return ModelDef(
        name=f"resnet_lite_c{n_classes}_b{blocks_per_stage}",
        params=specs,
        inputs=InputSpec(IMG, "f32", (), n_classes),
        loss_fn=loss_fn,
        flops_per_sample=flops,
    )


# ---------------------------------------------------------------------------
# Registry entries (names are what aot.py / rust configs refer to)
# ---------------------------------------------------------------------------


@register("alexnet_lite_c10")
def _a10():
    return _build_alexnet(10)


@register("alexnet_lite_c100")
def _a100():
    return _build_alexnet(100)


@register("vgg_lite_c10")
def _v10():
    return _build_vgg(10)


@register("vgg_lite_c100")
def _v100():
    return _build_vgg(100)


@register("resnet_lite_c10")
def _r10():
    return _build_resnet(10)


@register("resnet_lite_c100")
def _r100():
    return _build_resnet(100)


@register("resnet_deep_c1000")
def _r1000():
    # the ImageNet/ResNet-50 stand-in: deeper (2 blocks/stage), wider,
    # 1000-way head — used by the fig5/6/7 gradient-accumulation runs
    return _build_resnet(1000, blocks_per_stage=2, width=24)
