"""Model plumbing shared by the CNN family and the transformer.

A model here is a ``ModelDef``: an ordered parameter spec (name, shape,
init) plus apply functions. Parameters cross the python/rust boundary as a
*flat ordered list of f32 arrays* — the order in ``param_specs`` is the
contract, recorded in ``artifacts/manifest.json`` and consumed by
``rust/src/runtime/artifact.rs`` and ``optim/param.rs``. Keeping the
optimizer in rust (DESIGN.md §2) requires exactly this: rust must know
every parameter's shape, size and init recipe without importing python.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One learnable tensor. ``init`` is a recipe rust can reproduce:
    ("zeros",), ("ones",), ("normal", std), ("uniform", bound)."""

    name: str
    shape: Tuple[int, ...]
    init: Tuple

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class InputSpec:
    """Shape/dtype of one (micro)batch, with the batch axis symbolic."""

    x_shape: Tuple[int, ...]  # per-sample shape (no batch axis)
    x_dtype: str  # "f32" | "i32"
    y_shape: Tuple[int, ...]  # per-sample label shape (() for class id)
    n_classes: int
    # number of label positions per sample (1 for images, seq_len for LM);
    # rust uses this to turn correct-counts into error rates.
    labels_per_sample: int = 1


@dataclasses.dataclass
class ModelDef:
    name: str
    params: List[ParamSpec]
    inputs: InputSpec
    # loss_fn(param_list, x, y) -> (mean_loss, correct_count)
    loss_fn: Callable
    flops_per_sample: int  # analytic fwd flops (Section 3.3 accounting)

    def param_index(self) -> Dict[str, int]:
        return {p.name: i for i, p in enumerate(self.params)}

    def init_params(self, seed: int = 0) -> List[jax.Array]:
        """Reference initializer (tests only — rust owns the real init)."""
        out = []
        key = jax.random.PRNGKey(seed)
        for p in self.params:
            key, sub = jax.random.split(key)
            kind = p.init[0]
            if kind == "zeros":
                out.append(jnp.zeros(p.shape, jnp.float32))
            elif kind == "ones":
                out.append(jnp.ones(p.shape, jnp.float32))
            elif kind == "normal":
                out.append(jax.random.normal(sub, p.shape, jnp.float32) * p.init[1])
            elif kind == "uniform":
                b = p.init[1]
                out.append(jax.random.uniform(sub, p.shape, jnp.float32, -b, b))
            else:
                raise ValueError(f"unknown init {p.init!r}")
        return out


def he_normal_std(fan_in: int) -> float:
    return math.sqrt(2.0 / fan_in)


def glorot_uniform_bound(fan_in: int, fan_out: int) -> float:
    return math.sqrt(6.0 / (fan_in + fan_out))


class ParamBuilder:
    """Accumulates ParamSpecs while a model topology is being declared and
    hands each layer its parameter indices."""

    def __init__(self) -> None:
        self.specs: List[ParamSpec] = []

    def add(self, name: str, shape: Sequence[int], init: Tuple) -> int:
        idx = len(self.specs)
        self.specs.append(ParamSpec(name, tuple(int(s) for s in shape), init))
        return idx

    def conv(self, name: str, kh: int, kw: int, cin: int, cout: int) -> Tuple[int, int]:
        """HWIO conv kernel + bias; He-normal init (fan_in = kh*kw*cin)."""
        w = self.add(f"{name}.w", (kh, kw, cin, cout), ("normal", he_normal_std(kh * kw * cin)))
        b = self.add(f"{name}.b", (cout,), ("zeros",))
        return w, b

    def dense(self, name: str, n_in: int, n_out: int) -> Tuple[int, int]:
        w = self.add(f"{name}.w", (n_in, n_out), ("uniform", glorot_uniform_bound(n_in, n_out)))
        b = self.add(f"{name}.b", (n_out,), ("zeros",))
        return w, b

    def bn(self, name: str, c: int) -> Tuple[int, int]:
        g = self.add(f"{name}.gamma", (c,), ("ones",))
        b = self.add(f"{name}.beta", (c,), ("zeros",))
        return g, b


# Registry: name -> () -> ModelDef. Populated by cnn.py / transformer.py.
MODEL_REGISTRY: Dict[str, Callable[[], ModelDef]] = {}


def register(name: str):
    def deco(fn):
        MODEL_REGISTRY[name] = fn
        return fn

    return deco


def get_model(name: str) -> ModelDef:
    if name not in MODEL_REGISTRY:
        raise KeyError(f"unknown model {name!r}; have {sorted(MODEL_REGISTRY)}")
    return MODEL_REGISTRY[name]()
