from . import cnn, common, transformer  # noqa: F401
from .common import MODEL_REGISTRY, get_model  # noqa: F401
