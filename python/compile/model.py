"""L2 step builders: the jitted functions that get AOT-lowered to HLO.

Two step kinds per model, both *pure* (no python on the request path):

  train_step(params..., x, y) -> (loss, correct, grads...)
      value_and_grad over the model's loss; gradients come back in the
      manifest's parameter order. The optimizer deliberately does NOT live
      here — the rust coordinator applies Eq. (2) so that AdaBatch's
      gradient accumulation (Eq. 5), all-reduce and effective-LR coupling
      can interpose between gradient production and the weight update.

  eval_step(params..., x, y) -> (loss, correct)
      forward-only; `correct` is the per-batch correct-prediction count
      emitted by the fused loss kernel.

Signatures use a *flat argument list* (not pytrees) because the rust side
feeds positional PJRT literals.
"""

from __future__ import annotations

from typing import Callable, List

import jax
import jax.numpy as jnp

from .models.common import ModelDef


def make_train_step(model: ModelDef) -> Callable:
    n = len(model.params)

    def step(*args):
        params = list(args[:n])
        x, y = args[n], args[n + 1]

        def lossf(params: List[jax.Array]):
            loss, correct = model.loss_fn(params, x, y)
            return loss, correct

        (loss, correct), grads = jax.value_and_grad(lossf, has_aux=True)(params)
        return (loss, correct, *grads)

    return step


def make_eval_step(model: ModelDef) -> Callable:
    n = len(model.params)

    def step(*args):
        params = list(args[:n])
        x, y = args[n], args[n + 1]
        loss, correct = model.loss_fn(params, x, y)
        return (loss, correct)

    return step


def example_args(model: ModelDef, batch: int):
    """ShapeDtypeStructs for jit.lower: params..., x, y."""
    specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in model.params]
    xd = jnp.float32 if model.inputs.x_dtype == "f32" else jnp.int32
    x = jax.ShapeDtypeStruct((batch, *model.inputs.x_shape), xd)
    y = jax.ShapeDtypeStruct((batch, *model.inputs.y_shape), jnp.int32)
    return (*specs, x, y)
