"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an oracle here; pytest (and hypothesis
sweeps) assert ``allclose(kernel(...), ref(...))``. These are the CORE
correctness signal for Layer 1: the kernels must match these to numerical
tolerance across shapes, and the L2 model is free to swap between the two
(``use_pallas`` flag) without changing semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_bias_act(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none") -> jax.Array:
    """``act(x @ w + b)`` — oracle for kernels.matmul.matmul_bias_act.

    x: [m, k], w: [k, n], b: [n] -> [m, n]
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    return apply_act(y, act)


def apply_act(y: jax.Array, act: str) -> jax.Array:
    if act == "none":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "gelu":
        # tanh approximation (matches the kernel's closed form)
        c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
        return 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))
    raise ValueError(f"unknown act {act!r}")


def act_grad(y: jax.Array, act: str) -> jax.Array:
    """d act(y) / d y evaluated at pre-activation y."""
    if act == "none":
        return jnp.ones_like(y)
    if act == "relu":
        return (y > 0.0).astype(y.dtype)
    if act == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
        inner = c * (y + 0.044715 * y**3)
        t = jnp.tanh(inner)
        dinner = c * (1.0 + 3 * 0.044715 * y**2)
        return 0.5 * (1.0 + t) + 0.5 * y * (1.0 - t**2) * dinner
    raise ValueError(f"unknown act {act!r}")


def softmax_xent(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.softmax_xent.softmax_xent_loss.

    logits: [r, M] float32, labels: [r] int32 class ids.
    Returns (mean_loss: scalar, correct_count: scalar f32) — Eq. (9)-(12)
    of the paper with the 1/r batch mean folded in.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1)) + m[:, 0]
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - picked)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, correct


def softmax_xent_grad(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """d mean_loss / d logits = (p - z*) / r  (paper Eq. 17 with batch mean)."""
    r = logits.shape[0]
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    return (p - onehot) / r


def sgd_momentum_update(
    p: jax.Array,
    g: jax.Array,
    v: jax.Array,
    lr,
    momentum: float,
    weight_decay: float,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.sgd.sgd_momentum — PyTorch-style SGD w/ momentum.

    v' = mu * v + (g + wd * p);  p' = p - lr * v'
    (the α/r scaling of paper Eq. (2) is applied by the caller: gradients
    arriving here are already batch-mean gradients).
    """
    v_new = momentum * v + (g + weight_decay * p)
    p_new = p - lr * v_new
    return p_new, v_new


def batchnorm_forward(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5
) -> jax.Array:
    """Oracle for kernels.batchnorm.batchnorm2d — per-feature batch norm.

    x: [r, f] (features last; conv callers reshape NHWC -> [r*h*w, c]).
    Paper Appendix A.4, Eq. (37)-(40).
    """
    mu = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=0, keepdims=True)
    xhat = (x - mu) / jnp.sqrt(var + eps)
    return xhat * gamma[None, :] + beta[None, :]
