"""Batch-normalization forward as a Pallas kernel (paper Appendix A.4).

BN is the one layer whose *semantics* depend on the batch size r
(Eq. 37-40 normalize over the batch), which is why AdaBatch's claim that
schedule equivalence holds for VGG19_BN / ResNet matters: the statistics
get better-conditioned, not different in expectation, as r grows. The
kernel computes the biased batch statistics in one VMEM pass per feature
tile and applies the affine transform — cost O(m r), linear in r as
Appendix A.4 requires.

Layout: callers flatten NHWC conv activations to [rows = r*h*w, features=c]
so both conv BN ("spatial" statistics) and FC BN share one kernel. The
feature axis is tiled; the row axis is kept whole per tile so the reduction
needs no cross-program accumulation (rows for our models fit VMEM; the
estimate is in DESIGN.md §Perf).

Differentiation: the L2 model uses this kernel inside a ``jax.custom_vjp``
pair whose backward is the jnp closed form of Eq. (46)-(49) — BN backward
is bandwidth-bound elementwise work that XLA fuses well, so a dedicated
backward kernel would buy nothing under interpret mode (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_FEAT_TILE = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _bn_kernel(x_ref, gamma_ref, beta_ref, o_ref, *, rows: int, eps: float):
    x = x_ref[...]
    nrows = x.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    valid = row < rows
    xv = jnp.where(valid, x, 0.0)
    mu = jnp.sum(xv, axis=0, keepdims=True) / rows
    d = jnp.where(valid, x - mu, 0.0)
    var = jnp.sum(d * d, axis=0, keepdims=True) / rows
    xhat = d * jax.lax.rsqrt(var + eps)
    o_ref[...] = xhat * gamma_ref[...][None, :] + beta_ref[...][None, :]


def batchnorm2d(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Batch norm over axis 0 of ``x: [rows, features]``."""
    rows, feats = x.shape
    ft = min(_FEAT_TILE, max(8, 1 << (feats - 1).bit_length()))
    fp = _ceil_div(feats, ft) * ft
    rp = max(8, 1 << (rows - 1).bit_length())
    xp = jnp.pad(x, ((0, rp - rows), (0, fp - feats)))
    gp = jnp.pad(gamma, (0, fp - feats))
    bp = jnp.pad(beta, (0, fp - feats))
    out = pl.pallas_call(
        functools.partial(_bn_kernel, rows=rows, eps=eps),
        grid=(fp // ft,),
        in_specs=[
            pl.BlockSpec((rp, ft), lambda j: (0, j)),
            pl.BlockSpec((ft,), lambda j: (j,)),
            pl.BlockSpec((ft,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((rp, ft), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rp, fp), jnp.float32),
        interpret=True,
    )(xp, gp, bp)
    return out[:rows, :feats]


# Differentiable wrapper: Pallas forward, closed-form jnp backward
# (Eq. 46-49 in matrix form).


@functools.partial(jax.custom_vjp)
def batchnorm2d_vjp(x, gamma, beta):
    return batchnorm2d(x, gamma, beta)


def _bn_fwd(x, gamma, beta):
    eps = 1e-5
    mu = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=0, keepdims=True)
    out = batchnorm2d(x, gamma, beta, eps)
    return out, (x, gamma, mu, var)


def _bn_bwd(res, g):
    x, gamma, mu, var = res
    eps = 1e-5
    r = x.shape[0]
    inv = jax.lax.rsqrt(var + eps)
    xhat = (x - mu) * inv
    dgamma = jnp.sum(g * xhat, axis=0)
    dbeta = jnp.sum(g, axis=0)
    # Eq. (49): D^{-1} W (Vhat - D^{-2}(Uhat o Yhat)) in per-feature form
    dx = (gamma[None, :] * inv) * (
        g - jnp.mean(g, axis=0, keepdims=True) - xhat * jnp.mean(g * xhat, axis=0, keepdims=True)
    )
    return dx, dgamma, dbeta


batchnorm2d_vjp.defvjp(_bn_fwd, _bn_bwd)
