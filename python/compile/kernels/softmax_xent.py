"""Fused softmax + cross-entropy Pallas kernel with analytic backward.

Implements the paper's loss (Appendix A.1, Eq. 9-12): per-sample cross
entropy E(x) = -log p_i* with softmax p (Eq. 11), batch-mean reduced with
the 1/r factor the update rule (Eq. 2) expects. The backward is the
closed-form (p - z*)/r of Eq. 17 — also a Pallas kernel, so no softmax is
re-materialized by autodiff.

Fusing max/exp/sum/log into one VMEM-resident pass over the [r, M] logits
tile is the classic serving/training fusion; here it also keeps the loss
reduction linear in r (Section 3.3 invariant). The kernel additionally
emits the per-batch correct-prediction count so evaluation needs no second
pass over the logits.

Grid: one program per batch row-tile; the class axis is kept whole in VMEM
(M <= a few thousand for our models; the padded class tail is masked with
-inf so it cannot win max/argmax or contribute to the partition function).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ROW_TILE = 128
_NEG_INF = -1e30


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _fwd_kernel(logits_ref, labels_ref, loss_ref, correct_ref, *, n_classes: int):
    """Per row-tile: masked logsumexp loss sum + correct count."""
    z = logits_ref[...]
    lab = labels_ref[...]
    rows, cols = z.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    class_mask = col < n_classes
    zm = jnp.where(class_mask, z, _NEG_INF)
    # valid rows are flagged with label >= 0 (padding rows use -1)
    valid = lab >= 0
    m = jnp.max(zm, axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(zm - m[:, None]), axis=-1)) + m
    lab_safe = jnp.where(valid, lab, 0)
    picked = jnp.sum(jnp.where(col == lab_safe[:, None], zm, 0.0), axis=-1)
    losses = jnp.where(valid, lse - picked, 0.0)
    pred = jnp.argmax(zm, axis=-1).astype(jnp.int32)
    corr = jnp.where(valid & (pred == lab_safe), 1.0, 0.0)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        correct_ref[...] = jnp.zeros_like(correct_ref)

    loss_ref[...] += jnp.sum(losses)[None]
    correct_ref[...] += jnp.sum(corr)[None]


def _bwd_kernel(logits_ref, labels_ref, g_ref, dlogits_ref, *, n_classes: int, inv_r: float):
    """(p - onehot) * g / r per row-tile (Eq. 17 with batch mean)."""
    z = logits_ref[...]
    lab = labels_ref[...]
    rows, cols = z.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    class_mask = col < n_classes
    zm = jnp.where(class_mask, z, _NEG_INF)
    valid = lab >= 0
    m = jnp.max(zm, axis=-1, keepdims=True)
    e = jnp.exp(zm - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    onehot = (col == jnp.where(valid, lab, -2)[:, None]).astype(z.dtype)
    d = (p - onehot) * inv_r * g_ref[0]
    d = jnp.where(class_mask & valid[:, None], d, 0.0)
    dlogits_ref[...] = d


def _pad_rows(r: int) -> int:
    return _ceil_div(r, _ROW_TILE) * _ROW_TILE if r > _ROW_TILE else max(8, 1 << (r - 1).bit_length())


@jax.custom_vjp
def softmax_xent_loss(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(mean_loss, correct_count) over a batch. logits [r, M] f32, labels [r] i32."""
    return _run_fwd(logits, labels)[:2]


def _run_fwd(logits, labels):
    r, n_classes = logits.shape
    rp = _pad_rows(r)
    tile = min(_ROW_TILE, rp)
    cp = max(8, 1 << (n_classes - 1).bit_length())
    zp = jnp.pad(logits, ((0, rp - r), (0, cp - n_classes)))
    lp = jnp.pad(labels.astype(jnp.int32), (0, rp - r), constant_values=-1)
    loss_sum, correct = pl.pallas_call(
        functools.partial(_fwd_kernel, n_classes=n_classes),
        grid=(rp // tile,),
        in_specs=[
            pl.BlockSpec((tile, cp), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(zp, lp)
    return loss_sum[0] / r, correct[0], (logits, labels)


def _vjp_fwd(logits, labels):
    loss, correct, res = _run_fwd(logits, labels)
    return (loss, correct), res


def _vjp_bwd(res, g):
    logits, labels = res
    gl, _gc = g  # correct-count is non-differentiable
    r, n_classes = logits.shape
    rp = _pad_rows(r)
    tile = min(_ROW_TILE, rp)
    cp = max(8, 1 << (n_classes - 1).bit_length())
    zp = jnp.pad(logits, ((0, rp - r), (0, cp - n_classes)))
    lp = jnp.pad(labels.astype(jnp.int32), (0, rp - r), constant_values=-1)
    d = pl.pallas_call(
        functools.partial(_bwd_kernel, n_classes=n_classes, inv_r=1.0 / r),
        grid=(rp // tile,),
        in_specs=[
            pl.BlockSpec((tile, cp), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), jnp.float32),
        interpret=True,
    )(zp, lp, jnp.reshape(gl, (1,)))
    return d[:r, :n_classes], None


softmax_xent_loss.defvjp(_vjp_fwd, _vjp_bwd)
