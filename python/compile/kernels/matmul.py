"""Tiled Pallas matmul with fused bias + activation, and its custom VJP.

This is the paper's compute hot-spot (Section 3.3 / Appendix A.2): for a
fully-connected layer with weights W in R^{m x n}, batched input
X in R^{n x r} and error gradient V in R^{m x r}, training cost is dominated
by the forward GEMM ``Y = W X`` (Eq. 6) and the backward GEMM
``U = W^T V`` (Eq. 7) — both O(mnr), *linear in the batch size r*. AdaBatch
relies on exactly this linearity: growing r grows per-iteration work but
leaves flops/epoch unchanged, so all the batch-size gain must come from
hardware efficiency. The kernel below is therefore tiled so that per-batch
work scales with whole extra tiles (the grid's m-axis), never with
re-decoration of the k/n axes.

Hardware adaptation (paper targets P100 CUDA; we tile for TPU):
  * the CUDA threadblock tiling of a GEMM becomes a Pallas ``BlockSpec``
    HBM->VMEM schedule: each grid step holds an (bm x bk) X-tile and a
    (bk x bn) W-tile in VMEM and accumulates into an (bm x bn) f32 output
    tile — the MXU-systolic analogue of shared-memory tiles;
  * tile sides default to 128 to match the 128x128 MXU; small problems
    clamp tiles to the (padded) problem size;
  * the accumulator lives in a VMEM scratch buffer across the k-grid to
    avoid HBM round-trips (double-buffering of the input tiles is
    implicit in Pallas' pipelined grid on real hardware).

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; interpret-mode lowers the kernel into plain HLO so the same
artifact runs under the rust runtime. Real-TPU perf is *estimated* from the
VMEM footprint + MXU utilization of these BlockSpecs in DESIGN.md §Perf.

AD: ``pallas_call`` has no general autodiff, so ``matmul_bias_act`` is a
``jax.custom_vjp`` whose forward AND both backward GEMMs
(dX = dY W^T, dW = X^T dY — Eq. 7 / Eq. 23) are themselves Pallas kernels.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

# Default MXU-aligned tile sides. On small problems we clamp to the padded
# problem dims so interpret-mode does not waste work on empty tiles.
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to [rows, cols]."""
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _tile_sizes(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Clamp the MXU-aligned tiles to the problem size (keeps interpret-mode
    cheap on the small shapes used in tests while preserving the 128-aligned
    schedule on real layer shapes)."""
    bm = min(TILE_M, max(8, 1 << (m - 1).bit_length()))
    bn = min(TILE_N, max(8, 1 << (n - 1).bit_length()))
    bk = min(TILE_K, max(8, 1 << (k - 1).bit_length()))
    return bm, bn, bk


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, act: str, bias_ref=None):
    """Grid = (m_tiles, n_tiles, k_tiles); k innermost. Accumulate the
    (bm x bn) f32 tile in VMEM scratch; on the last k step apply bias +
    activation and write out."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_idx == n_k - 1)
    def _finish():
        y = acc_ref[...]
        if bias_ref is not None:
            y = y + bias_ref[...][None, :]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        elif act == "gelu":
            c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
            y = 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))
        o_ref[...] = y.astype(o_ref.dtype)


def matmul_raw(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    act: str = "none",
) -> jax.Array:
    """``act(x @ w [+ bias])`` as a tiled Pallas kernel. x: [m,k], w: [k,n]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    bm, bn, bk = _tile_sizes(m, n, k)
    mp, np_, kp = _ceil_div(m, bm) * bm, _ceil_div(n, bn) * bn, _ceil_div(k, bk) * bk
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)
    n_k = kp // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [xp, wp]
    if bias is not None:
        bp = jnp.pad(bias, (0, np_ - n)) if np_ != n else bias
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        args.append(bp)
        kern = functools.partial(
            _wrapped_bias_kernel, n_k=n_k, act=act
        )
    else:
        kern = functools.partial(_matmul_kernel, n_k=n_k, act=act)

    out = pl.pallas_call(
        kern,
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(*args)
    return out[:m, :n]


def _wrapped_bias_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, act: str):
    _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, n_k=n_k, act=act, bias_ref=b_ref)


# ---------------------------------------------------------------------------
# custom_vjp wrapper: the differentiable fused FC layer primitive used by L2.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_bias_act(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none") -> jax.Array:
    """Differentiable fused ``act(x @ w + b)`` where fwd and bwd GEMMs are
    Pallas kernels. Matches ``ref.matmul_bias_act`` exactly in semantics."""
    return matmul_raw(x, w, bias=b, act=act)


def _fwd(x, w, b, act):
    # Save pre-activation y for the activation gradient (cheap to recompute
    # bias add; we recompute y = x@w+b lazily via the saved product? No —
    # save y itself: dact needs it and saving beats a third GEMM).
    y = matmul_raw(x, w, bias=b, act="none")
    out = ref.apply_act(y, act)
    return out, (x, w, y)


def _bwd(act, res, g):
    x, w, y = res
    dy = g * ref.act_grad(y, act)
    # Backward GEMMs as Pallas kernels (paper Eq. 7: U = W^T V, Eq. 23:
    # dW = sum_i v_i x_i^T == X^T dY in batch-matrix form).
    dx = matmul_raw(dy, w.T)
    dw = matmul_raw(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


matmul_bias_act.defvjp(_fwd, _bwd)
