"""Fused SGD-with-momentum + weight-decay Pallas update kernel.

Implements the paper's update rule (Eq. 2 / Eq. 8):

    W_{i+1} = W_i - (alpha/r) * dW_i

in its momentum form (momentum 0.9 + weight decay are what every
experiment in Section 4 uses):

    v' = mu * v + (g + wd * p)       p' = p - lr * v'

The lr handed to this kernel is the *per-sample-mean* learning rate — the
1/r of Eq. (2) is already folded into the batch-mean gradient by the loss
kernel, which is exactly what keeps the AdaBatch effective-LR contract: when
the coordinator doubles r and rescales alpha, this kernel is unchanged.

The kernel is a pure element-wise dual-output map over flat parameter
buffers — one HBM pass reading (p, g, v) and writing (p', v'), replacing
the three separate passes an unfused optimizer would take. lr arrives as a
scalar operand so a single compiled artifact serves every point of the LR
schedule.

This kernel exists for the optional fused-train-step artifact; the default
architecture applies updates in the rust coordinator (see DESIGN.md §2) so
that gradient accumulation and all-reduce can interpose. Both paths are
tested against ``ref.sgd_momentum_update``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TILE = 1024


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _sgd_kernel(lr_ref, p_ref, g_ref, v_ref, p_out, v_out, *, momentum: float, weight_decay: float):
    p = p_ref[...]
    g = g_ref[...] + weight_decay * p
    v = momentum * v_ref[...] + g
    v_out[...] = v
    p_out[...] = p - lr_ref[0] * v


def sgd_momentum(
    p: jax.Array,
    g: jax.Array,
    v: jax.Array,
    lr: jax.Array,
    momentum: float = 0.9,
    weight_decay: float = 5e-4,
) -> tuple[jax.Array, jax.Array]:
    """Fused (p', v') update over a flat f32 buffer. lr: scalar array."""
    assert p.ndim == 1 and p.shape == g.shape == v.shape
    n = p.shape[0]
    tile = min(_TILE, max(8, 1 << (n - 1).bit_length()))
    np_ = _ceil_div(n, tile) * tile
    pad = np_ - n
    pp, gp, vp = (jnp.pad(a, (0, pad)) for a in (p, g, v))
    p2, v2 = pl.pallas_call(
        functools.partial(_sgd_kernel, momentum=momentum, weight_decay=weight_decay),
        grid=(np_ // tile,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=True,
    )(jnp.reshape(lr, (1,)), pp, gp, vp)
    return p2[:n], v2[:n]
